//! `cgra` — command-line front end of the OpenEdgeCGRA reproduction.
//! Every subcommand drives one shared [`Engine`] session.
//!
//! ```text
//! cgra run     --mapping wp --c 16 --k 16 --ox 16 --oy 16   one convolution
//! cgra plan    [--c ...] | --validate | --network            cost model: predict, don't simulate
//! cgra report  fig3|fig4|fig5|all [--out DIR] [--full]      regenerate figures
//! cgra sweep   [--full] [--out DIR]                          Fig. 5 sweep
//! cgra net     [--preset NAME] [--plan-only]                 edge network on the CGRA (nn)
//! cgra compile [--preset NAME] [--out FILE]                  compile to a CompiledNet, summarize;
//!                                                             --out serializes the AOT artifact
//! cgra serve   --iters N [--batch B] [--preset NAME]         compile once, serve N inferences
//!              [--verify] [--artifact FILE]                   (B lanes per µop walk when batched;
//!                                                             --artifact loads, zero rebuilds)
//! cgra daemon  [--port P] [--workers W] [--batch B]          persistent NDJSON/TCP serving:
//!              [--capacity N] [--admission reject|degrade]    registry + admission + stats
//!              [--artifact-dir DIR]                           (disk-backed registry tier)
//! cgra trace   [--preset NAME] [--iters N] [--out FILE]      run compiled inferences under the
//!                                                             span tracer, write Chrome JSON
//! cgra profile [--preset NAME | --mapping M --shape CxKxOXxOY] cycle-attribution profiler:
//!              [--iters N] [--out FILE.json]                  per-PE / per-bank bottleneck report
//! cgra verify  [--artifacts DIR]                             CGRA vs XLA artifact
//! cgra asm     FILE.casm                                     assemble + run + dump
//! ```

use anyhow::{bail, Context, Result};

use openedge_cgra::cgra::Memory;
use openedge_cgra::conv::{random_input, random_weights, ConvShape};
use openedge_cgra::coordinator::{default_workers, ConvNet, SweepSpec};
use openedge_cgra::engine::{ConvRequest, Engine, EngineBuilder};
use openedge_cgra::kernels::Mapping;
use openedge_cgra::prop::Rng;
use openedge_cgra::report;
use openedge_cgra::util::{Args, OptSpec};

fn main() {
    if let Err(e) = dispatch() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const USAGE: &str =
    "usage: cgra <run|plan|report|sweep|net|compile|serve|daemon|trace|profile|verify|asm> \
     [options]\n\
     see README.md for per-command options";

fn dispatch() -> Result<()> {
    let cmd = std::env::args().nth(1).unwrap_or_default();
    match cmd.as_str() {
        "run" => cmd_run(),
        "plan" => cmd_plan(),
        "report" => cmd_report(),
        "sweep" => cmd_sweep(),
        "net" => cmd_net(),
        "compile" => cmd_compile(),
        "serve" => cmd_serve(),
        "daemon" => cmd_daemon(),
        "trace" => cmd_trace(),
        "profile" => cmd_profile(),
        "verify" => cmd_verify(),
        "asm" => cmd_asm(),
        "" | "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn shape_from(a: &Args) -> Result<ConvShape> {
    // The validating constructor: zero/oversized dimensions fail here
    // with an actionable message instead of panicking downstream.
    ConvShape::checked(
        a.num_or("c", 16usize)?,
        a.num_or("k", 16usize)?,
        a.num_or("ox", 16usize)?,
        a.num_or("oy", 16usize)?,
    )
}

fn engine_with_workers(workers: usize) -> Result<Engine> {
    EngineBuilder::new().workers(workers).build()
}

fn cmd_run() -> Result<()> {
    let a = Args::from_env(
        2,
        &[],
        vec![
            OptSpec {
                name: "mapping",
                value: "wp|ip|im2col-op|conv-op|dw|cpu|auto|all",
                help: "strategy (auto lets the engine pick; dw = depthwise Dw-WP, \
                       needs k == c, not part of 'all' — it computes a different operator)",
            },
            OptSpec { name: "c", value: "INT", help: "input channels" },
            OptSpec { name: "k", value: "INT", help: "output channels" },
            OptSpec { name: "ox", value: "INT", help: "output rows" },
            OptSpec { name: "oy", value: "INT", help: "output cols" },
            OptSpec { name: "seed", value: "INT", help: "data seed" },
            OptSpec { name: "workers", value: "INT", help: "worker threads" },
        ],
    )?;
    let shape = shape_from(&a)?;
    let seed = a.num_or("seed", 42u64)?;
    let which = a.str_or("mapping", "all");
    let workers = a.num_or("workers", default_workers())?;
    a.reject_unknown()?;

    let engine = engine_with_workers(workers)?;
    let mappings: Vec<Mapping> = if which == "all" {
        Mapping::ALL.to_vec()
    } else {
        vec![Mapping::parse(&which)?]
    };

    // Explicit tensors keep the golden check honest: these requests are
    // never served from the cache, so "exact" always reflects a real
    // simulation.
    let mut rng = Rng::new(seed);
    let input = random_input(&shape, 30, &mut rng);
    let weights = random_weights(&shape, 9, &mut rng);
    let golden = openedge_cgra::conv::conv2d(&shape, &input, &weights);
    // The depthwise operator has its own filter bank and golden model;
    // reject impossible requests up front with the kernel's diagnostic
    // instead of a downstream weight-count mismatch.
    if mappings.contains(&Mapping::DwWp) && shape.k != shape.c {
        bail!(
            "depthwise convention: K must equal C (one filter per channel), \
             got K={} C={} — pass matching --c/--k for --mapping dw",
            shape.k,
            shape.c
        );
    }
    let dw_data = (shape.k == shape.c && mappings.contains(&Mapping::DwWp)).then(|| {
        let mut rng = Rng::new(seed ^ 0xd3);
        let w = openedge_cgra::conv::random_depthwise_weights(&shape, 9, &mut rng);
        let golden = openedge_cgra::conv::depthwise2d(&shape, &input, &w);
        (w, golden)
    });
    let reqs: Vec<ConvRequest> = mappings
        .iter()
        .map(|&m| match (m, &dw_data) {
            (Mapping::DwWp, Some((w, _))) => {
                ConvRequest::with_data(shape, m, input.clone(), w.clone())
            }
            _ => ConvRequest::with_data(shape, m, input.clone(), weights.clone()),
        })
        .collect();

    println!("layer {shape}  ({} MACs)\n", shape.macs());
    let mut table = openedge_cgra::util::fmt::Table::new(&[
        "mapping", "cycles", "MAC/cycle", "energy_uJ", "power_mW", "memory", "exact",
    ]);
    let mut decisions = Vec::new();
    let mut failures: Vec<(Mapping, anyhow::Error)> = Vec::new();
    for (&m, res) in mappings.iter().zip(engine.submit_batch(&reqs)) {
        match res {
            Ok(res) => {
                let exact = match (&res.mapping, &dw_data) {
                    (Mapping::DwWp, Some((_, dw_golden))) => res.output.data == dw_golden.data,
                    _ => res.output.data == golden.data,
                };
                let r = &res.report;
                table.row(vec![
                    res.mapping.label().into(),
                    r.latency_cycles.to_string(),
                    format!("{:.3}", r.mac_per_cycle),
                    format!("{:.2}", r.energy_uj),
                    format!("{:.2}", r.avg_power_mw),
                    openedge_cgra::util::fmt::kib(r.footprint_bytes),
                    if exact { "yes".into() } else { "NO".into() },
                ]);
                if let Some(d) = res.auto {
                    decisions.push(d);
                }
            }
            // Per-mapping failures (e.g. the 512 KiB bound) keep their
            // row and never discard the completed mappings.
            Err(e) => {
                table.row(vec![
                    m.label().into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "skipped".into(),
                ]);
                failures.push((m, e));
            }
        }
    }
    print!("{}", table.render());
    for d in decisions {
        println!("{d}");
    }
    for (m, e) in &failures {
        println!("{}: skipped — {e:#}", m.label());
    }
    if failures.len() == mappings.len() {
        bail!("every requested mapping failed");
    }
    Ok(())
}

/// `cgra plan` — drive the analytical cost model: predict a layer's
/// cost per mapping (default), validate predictions against the
/// simulator (`--validate`, the CI accuracy gate), or plan a CNN layer
/// by layer (`--network`).
fn cmd_plan() -> Result<()> {
    let a = Args::from_env(
        2,
        &["validate", "full", "network"],
        vec![
            OptSpec { name: "c", value: "INT", help: "input channels" },
            OptSpec { name: "k", value: "INT", help: "output channels" },
            OptSpec { name: "ox", value: "INT", help: "output rows" },
            OptSpec { name: "oy", value: "INT", help: "output cols" },
            OptSpec {
                name: "mapping",
                value: "wp|ip|im2col-op|conv-op|dw|cpu|auto|all",
                help: "strategy to cost (default: all + the auto choice; dw = depthwise \
                       Dw-WP, needs k == c, not part of 'all')",
            },
            OptSpec { name: "validate", value: "", help: "predicted-vs-simulated sweep" },
            OptSpec { name: "full", value: "", help: "validate on the full paper grid (slow)" },
            OptSpec {
                name: "max-mae",
                value: "PCT",
                help: "with --validate: fail when mean |latency err| exceeds this (default 5)",
            },
            OptSpec { name: "network", value: "", help: "plan a random CNN per layer" },
            OptSpec { name: "depth", value: "INT", help: "network: conv layers" },
            OptSpec { name: "c0", value: "INT", help: "network: input channels" },
            OptSpec { name: "hw", value: "INT", help: "network: input height=width" },
            OptSpec { name: "seed", value: "INT", help: "network: weight seed" },
            OptSpec {
                name: "objective",
                value: "latency|energy",
                help: "network: what the plan minimizes (default latency)",
            },
            OptSpec { name: "workers", value: "INT", help: "worker threads (validate)" },
            OptSpec { name: "out", value: "DIR", help: "save the validation report" },
        ],
    )?;
    let engine = engine_with_workers(a.num_or("workers", default_workers())?)?;
    if a.flag("validate") {
        let spec = if a.flag("full") { SweepSpec::paper() } else { SweepSpec::validation() };
        let max_mae: f64 = a.num_or("max-mae", 5.0)?;
        let out_dir = a.opt_str("out").map(std::path::PathBuf::from);
        a.reject_unknown()?;
        let (fig, report) = openedge_cgra::report::planner_fig(&engine, &spec)?;
        println!("{}", fig.text);
        if let Some(dir) = &out_dir {
            fig.save(dir)?;
            std::fs::write(dir.join("planner.json"), report.to_json().to_string_pretty())?;
            println!("saved {}/planner.{{txt,csv,json}}", dir.display());
        }
        anyhow::ensure!(
            !report.rows.is_empty(),
            "validation grid produced no comparable points — nothing was validated"
        );
        anyhow::ensure!(
            report.bound_mismatches == 0,
            "planner and simulator disagree on feasibility for {} points:\n  {}",
            report.bound_mismatches,
            report.mismatch_details.join("\n  ")
        );
        anyhow::ensure!(
            report.mean_abs_latency_err_pct <= max_mae,
            "planner mean |latency error| {:.3}% exceeds the {max_mae}% bound",
            report.mean_abs_latency_err_pct
        );
        println!(
            "planner accuracy OK: mean |latency err| {:.3}% <= {max_mae}%",
            report.mean_abs_latency_err_pct
        );
        // Composition cross-check: does the launch-class decomposition
        // predict *where* the cycles go (DESIGN.md §12)? The latency
        // gate above only bounds how many there are.
        let bc = openedge_cgra::planner::bottleneck_check(
            &engine,
            &ConvShape::checked(4, 4, 8, 8)?,
            Mapping::Wp,
            11,
        )?;
        println!("\n{}", bc.render());
        anyhow::ensure!(
            bc.max_share_err_pp <= 5.0,
            "predicted bottleneck composition off by {:.3} pp (> 5 pp bound)",
            bc.max_share_err_pp
        );
        return Ok(());
    }
    if a.flag("network") {
        let depth = a.num_or("depth", 4usize)?;
        let c0 = a.num_or("c0", 3usize)?;
        let k = a.num_or("k", 16usize)?;
        let hw = a.num_or("hw", 32usize)?;
        let seed = a.num_or("seed", 7u64)?;
        let objective =
            openedge_cgra::planner::PlanObjective::parse(&a.str_or("objective", "latency"))?;
        a.reject_unknown()?;
        let net = ConvNet::random(depth, c0, k, hw, hw, seed);
        let plan = engine.plan_network(&net, objective)?;
        println!(
            "planned CNN ({} layers, objective: {}) — no layer was simulated\n",
            plan.layers.len(),
            plan.objective.label()
        );
        let mut table = openedge_cgra::util::fmt::Table::new(&[
            "layer", "shape", "mapping", "pred_cycles", "pred_uJ", "relu_cycles",
        ]);
        for l in &plan.layers {
            table.row(vec![
                l.index.to_string(),
                l.shape.id(),
                l.mapping.label().into(),
                l.estimate.cycles().to_string(),
                format!("{:.2}", l.estimate.energy_uj()),
                l.relu_cycles.to_string(),
            ]);
        }
        print!("{}", table.render());
        let stats = engine.planner().stats();
        println!(
            "\npredicted total: {} cycles, {:.2} uJ ({} probe launches to calibrate)",
            plan.total_cycles, plan.total_energy_uj, stats.probe_launches
        );
        return Ok(());
    }
    // Default: cost one layer across mappings, plus the auto choice.
    let shape = shape_from(&a)?;
    let which = a.str_or("mapping", "all");
    a.reject_unknown()?;
    let mappings: Vec<Mapping> = if which == "all" {
        Mapping::ALL.to_vec()
    } else {
        vec![Mapping::parse(&which)?]
    };
    println!("layer {shape}  ({} MACs) — predicted, not simulated\n", shape.macs());
    let mut table = openedge_cgra::util::fmt::Table::new(&[
        "mapping", "pred_cycles", "MAC/cycle", "pred_uJ", "power_mW", "memory", "launches",
    ]);
    let mut failures = Vec::new();
    for m in mappings {
        if m.is_auto() {
            continue; // reported via the decision line below
        }
        match engine.plan(&shape, m) {
            Ok(est) => {
                table.row(vec![
                    m.label().into(),
                    est.report.latency_cycles.to_string(),
                    format!("{:.3}", est.report.mac_per_cycle),
                    format!("{:.2}", est.report.energy_uj),
                    format!("{:.2}", est.report.avg_power_mw),
                    openedge_cgra::util::fmt::kib(est.report.footprint_bytes),
                    est.report.launches.to_string(),
                ]);
            }
            Err(e) => {
                table.row(vec![
                    m.label().into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "skipped".into(),
                ]);
                failures.push((m, e));
            }
        }
    }
    print!("{}", table.render());
    for (m, e) in &failures {
        println!("{}: skipped — {e:#}", m.label());
    }
    match engine.submit_planned(&ConvRequest::seeded(shape, Mapping::Auto, 0)) {
        Ok(planned) => {
            println!("{}", planned.auto.expect("auto requested"));
            let stats = engine.planner().stats();
            println!(
                "({} probe launches simulated to calibrate; repeats are memo lookups)",
                stats.probe_launches
            );
        }
        Err(e) => println!("auto: unavailable — {e:#}"),
    }
    Ok(())
}

fn cmd_report() -> Result<()> {
    let a = Args::from_env(
        3,
        &["full"],
        vec![
            OptSpec { name: "out", value: "DIR", help: "directory for .txt/.csv output" },
            OptSpec { name: "full", value: "", help: "full paper sweep for fig5 (slow)" },
            OptSpec { name: "workers", value: "INT", help: "worker threads" },
        ],
    )?;
    let which = std::env::args().nth(2).unwrap_or_else(|| "all".into());
    let workers = a.num_or("workers", default_workers())?;
    let full = a.flag("full");
    let out_dir = a.opt_str("out").map(std::path::PathBuf::from);
    a.reject_unknown()?;

    let engine = engine_with_workers(workers)?;
    let spec = if full { SweepSpec::paper() } else { SweepSpec::quick() };
    let figures: Vec<report::Figure> = match which.as_str() {
        "fig3" => vec![report::fig3(&engine)?],
        "fig4" => vec![report::fig4(&engine)?],
        "fig5" => vec![report::fig5(&engine, &spec)?],
        "all" => vec![
            report::fig3(&engine)?,
            report::fig4(&engine)?,
            report::fig5(&engine, &spec)?,
        ],
        other => bail!("unknown figure '{other}' (fig3|fig4|fig5|all)"),
    };
    for f in &figures {
        println!("{}\n", f.text);
        if let Some(dir) = &out_dir {
            f.save(dir)?;
            println!("saved {}/{}.{{txt,csv}}", dir.display(), f.id);
        }
    }
    Ok(())
}

fn cmd_sweep() -> Result<()> {
    let a = Args::from_env(
        2,
        &["full"],
        vec![
            OptSpec { name: "full", value: "", help: "full paper grid (slow)" },
            OptSpec { name: "out", value: "DIR", help: "output directory" },
            OptSpec { name: "workers", value: "INT", help: "worker threads" },
        ],
    )?;
    let workers = a.num_or("workers", default_workers())?;
    let spec = if a.flag("full") { SweepSpec::paper() } else { SweepSpec::quick() };
    let out_dir = a.opt_str("out").map(std::path::PathBuf::from);
    a.reject_unknown()?;
    let engine = engine_with_workers(workers)?;
    let f = report::fig5(&engine, &spec)?;
    println!("{}", f.text);
    if let Some(dir) = out_dir {
        f.save(&dir)?;
    }
    Ok(())
}

/// `cgra net` — run (or plan) an edge network end to end on the
/// simulated CGRA through the `nn` layer-graph subsystem: generalized
/// convolutions (stride / padding / groups), depthwise (`Dw-WP`) and
/// pointwise layers, pooling, per-layer planner-chosen mappings.
fn cmd_net() -> Result<()> {
    let a = Args::from_env(
        2,
        &["plan-only"],
        vec![
            OptSpec {
                name: "preset",
                value: "NAME",
                help: "named network: mobilenet-mini | paper-baseline | vgg-mini \
                       (default: a plain --depth/--c0/--k/--hw conv stack)",
            },
            OptSpec {
                name: "plan-only",
                value: "",
                help: "predict per-layer cost via the planner, simulate nothing",
            },
            OptSpec {
                name: "objective",
                value: "latency|energy",
                help: "what --plan-only minimizes per layer (default latency)",
            },
            OptSpec { name: "depth", value: "INT", help: "plain stack: conv layers" },
            OptSpec { name: "c0", value: "INT", help: "plain stack: input channels" },
            OptSpec { name: "k", value: "INT", help: "plain stack: channels per layer" },
            OptSpec { name: "hw", value: "INT", help: "plain stack: input height=width" },
            OptSpec { name: "seed", value: "INT", help: "weight/data seed" },
            OptSpec { name: "out", value: "DIR", help: "save the report (.txt/.csv)" },
            OptSpec { name: "workers", value: "INT", help: "worker threads (group batches)" },
        ],
    )?;
    let seed = a.num_or("seed", 7u64)?;
    let preset = a.opt_str("preset").map(str::to_string);
    let depth = a.num_or("depth", 4usize)?;
    let c0 = a.num_or("c0", 3usize)?;
    let k = a.num_or("k", 16usize)?;
    let hw = a.num_or("hw", 32usize)?;
    let plan_only = a.flag("plan-only");
    let objective =
        openedge_cgra::planner::PlanObjective::parse(&a.str_or("objective", "latency"))?;
    let out_dir = a.opt_str("out").map(std::path::PathBuf::from);
    let workers = a.num_or("workers", default_workers())?;
    a.reject_unknown()?;

    let net = match &preset {
        Some(name) => openedge_cgra::nn::build_preset(name, seed)?,
        None => openedge_cgra::nn::Net::plain_stack(depth, c0, k, hw, seed)?,
    };
    let (c, h, w) = net.input_dims;
    println!(
        "network '{}': {} layers, {} true MACs, input {c}x{h}x{w}\n",
        net.name,
        net.layers.len(),
        net.macs()
    );

    let engine = engine_with_workers(workers)?;
    let fig = if plan_only {
        let plan = engine.planner();
        let netplan = openedge_cgra::nn::plan_network(plan, &net, objective)?;
        report::net_plan_fig(&netplan)
    } else {
        let input = net.random_input(8, seed ^ 0xabcd);
        let rep = openedge_cgra::nn::run_network(&engine, &net, &input)?;
        let fig = report::net_fig(&rep);
        if !rep.exact {
            println!("{}", fig.text);
            bail!("network output diverged from the generalized golden model");
        }
        fig
    };
    println!("{}", fig.text);
    if let Some(dir) = out_dir {
        fig.save(&dir)?;
        println!("saved {}/{}.{{txt,csv}}", dir.display(), fig.id);
    }
    Ok(())
}

/// Resolve the network a `compile`/`serve` invocation targets: a named
/// preset, or the plain `--depth/--c0/--k/--hw` conv stack.
fn net_from_args(a: &Args, seed: u64) -> Result<openedge_cgra::nn::Net> {
    match a.opt_str("preset") {
        Some(name) => openedge_cgra::nn::build_preset(name, seed),
        None => openedge_cgra::nn::Net::plain_stack(
            a.num_or("depth", 4usize)?,
            a.num_or("c0", 3usize)?,
            a.num_or("k", 16usize)?,
            a.num_or("hw", 32usize)?,
            seed,
        ),
    }
}

/// `cgra compile` — ahead-of-time compile a network into a
/// [`openedge_cgra::engine::CompiledNet`] and print the artifact
/// summary: per-layer frozen mapping, launch count and pre-decoded
/// µops, plus the arena sizing and the compile wall time. With
/// `--out FILE` the compiled network is serialized to disk
/// (DESIGN.md §13) for later zero-rebuild loading via
/// `cgra serve --artifact` or the daemon's `--artifact-dir` tier.
fn cmd_compile() -> Result<()> {
    let a = Args::from_env(
        2,
        &[],
        vec![
            OptSpec {
                name: "preset",
                value: "NAME",
                help: "named network: mobilenet-mini | paper-baseline | vgg-mini \
                       (default: a plain --depth/--c0/--k/--hw conv stack)",
            },
            OptSpec {
                name: "out",
                value: "FILE",
                help: "serialize the compiled network to this artifact file",
            },
            OptSpec { name: "depth", value: "INT", help: "plain stack: conv layers" },
            OptSpec { name: "c0", value: "INT", help: "plain stack: input channels" },
            OptSpec { name: "k", value: "INT", help: "plain stack: channels per layer" },
            OptSpec { name: "hw", value: "INT", help: "plain stack: input height=width" },
            OptSpec { name: "seed", value: "INT", help: "weight seed" },
        ],
    )?;
    let seed = a.num_or("seed", 7u64)?;
    let out = a.opt_str("out").map(str::to_string);
    let net = net_from_args(&a, seed)?;
    a.reject_unknown()?;

    let engine = EngineBuilder::new().build()?;
    let t0 = std::time::Instant::now();
    let compiled = engine.compile_owned(net)?;
    let compile_s = t0.elapsed().as_secs_f64();

    println!(
        "compiled '{}': {} layers, {} true MACs\n",
        compiled.name(),
        compiled.layer_count(),
        compiled.net().macs()
    );
    let mut table = openedge_cgra::util::fmt::Table::new(&[
        "layer", "kind", "shape", "mapping", "launches", "uops",
    ]);
    for i in 0..compiled.layer_count() {
        let info = compiled.layer_info(i);
        table.row(vec![
            i.to_string(),
            info.kind.into(),
            info.desc.to_string(),
            info.mapping.map(|m| m.label().to_string()).unwrap_or_else(|| "host".into()),
            info.launches.to_string(),
            info.uops.to_string(),
        ]);
    }
    print!("{}", table.render());
    for i in 0..compiled.layer_count() {
        if let Some(d) = compiled.layer_info(i).auto {
            println!("layer {i}: {d}");
        }
    }
    println!(
        "\nartifact: {} launches/inference, {} pre-decoded uops, \
         arena {} words ({}); compiled in {:.1} ms",
        compiled.total_launches(),
        compiled.total_uops(),
        compiled.arena_words(),
        openedge_cgra::util::fmt::kib(4 * compiled.arena_words()),
        compile_s * 1e3,
    );
    println!(
        "steady-state runs perform zero program building, zero decoding, \
         zero planner work, zero activation allocation (`cgra serve`)"
    );
    if let Some(path) = out {
        let info = compiled.save(std::path::Path::new(&path))?;
        println!(
            "\nwrote {path}: {} bytes on disk ({} payload), checksum {:016x}",
            info.file_bytes, info.payload_bytes, info.checksum
        );
        println!(
            "  net fp {:016x}, session fp {:016x} — load with `cgra serve --artifact {path}`",
            info.net_fp, info.session_fp
        );
    }
    Ok(())
}

/// `cgra serve` — the compile-once / run-many loop: compile the
/// network, then serve `--iters` inferences (fresh input per
/// iteration) over `--workers` threads sharing one `Arc<CompiledNet>`,
/// each worker replaying against its own context. `--batch B` runs B
/// inferences per shared µop walk (DESIGN.md §9) for bulk throughput;
/// modeled per-inference numbers are unchanged. `--verify` runs the
/// opt-in golden debug mode and exits non-zero on any divergence.
/// `--artifact FILE` skips compilation entirely and loads a
/// `cgra compile --out` artifact instead — zero program builds, zero
/// µop decodes, zero planner work on the load path.
fn cmd_serve() -> Result<()> {
    let a = Args::from_env(
        2,
        &["verify"],
        vec![
            OptSpec {
                name: "preset",
                value: "NAME",
                help: "named network: mobilenet-mini | paper-baseline | vgg-mini \
                       (default: a plain --depth/--c0/--k/--hw conv stack)",
            },
            OptSpec {
                name: "artifact",
                value: "FILE",
                help: "load a serialized compiled network instead of compiling \
                       (see `cgra compile --out`)",
            },
            OptSpec { name: "iters", value: "INT", help: "inferences to serve (default 16)" },
            OptSpec {
                name: "batch",
                value: "INT",
                help: "inference lanes per shared uop walk (default 1 = scalar)",
            },
            OptSpec { name: "workers", value: "INT", help: "worker threads" },
            OptSpec {
                name: "verify",
                value: "",
                help: "debug mode: golden-check every layer of every inference",
            },
            OptSpec { name: "depth", value: "INT", help: "plain stack: conv layers" },
            OptSpec { name: "c0", value: "INT", help: "plain stack: input channels" },
            OptSpec { name: "k", value: "INT", help: "plain stack: channels per layer" },
            OptSpec { name: "hw", value: "INT", help: "plain stack: input height=width" },
            OptSpec { name: "seed", value: "INT", help: "weight/data seed" },
        ],
    )?;
    let seed = a.num_or("seed", 7u64)?;
    let iters: u64 = a.num_or("iters", 16u64)?;
    let batch: usize = a.num_or("batch", 1usize)?;
    let workers = a.num_or("workers", default_workers())?;
    let verify = a.flag("verify");
    let artifact = a.opt_str("artifact").map(str::to_string);
    let net = if artifact.is_none() { Some(net_from_args(&a, seed)?) } else { None };
    a.reject_unknown()?;
    anyhow::ensure!(iters >= 1, "--iters must be at least 1");
    anyhow::ensure!(batch >= 1, "--batch must be at least 1");

    let engine = engine_with_workers(workers)?;
    let t0 = std::time::Instant::now();
    let compiled = match (&artifact, net) {
        (Some(path), _) => {
            let (cn, info) =
                openedge_cgra::engine::CompiledNet::load(&engine, std::path::Path::new(path))?;
            println!(
                "loaded artifact {path}: net '{}' fp {:016x}, session fp {:016x}, \
                 checksum {:016x} ({} bytes)",
                info.net, info.net_fp, info.session_fp, info.checksum, info.file_bytes
            );
            std::sync::Arc::new(cn)
        }
        (None, Some(net)) => std::sync::Arc::new(engine.compile_owned(net)?),
        (None, None) => unreachable!("net is resolved whenever --artifact is absent"),
    };
    let compile_s = t0.elapsed().as_secs_f64();
    println!(
        "{} '{}' in {:.1} ms ({} launches/inference, {} pre-decoded uops); \
         serving {iters} inferences on {workers} workers{}{}\n",
        if artifact.is_some() { "loaded" } else { "compiled" },
        compiled.name(),
        compile_s * 1e3,
        compiled.total_launches(),
        compiled.total_uops(),
        if batch > 1 { format!(" x {batch} batch lanes") } else { String::new() },
        if verify { " [debug-verify]" } else { "" },
    );

    // Contiguous iteration shards, one job per worker; each worker
    // allocates its context once and replays its share warm, `batch`
    // lanes per shared µop walk (ragged final chunk per shard).
    // `wall_us` collects the *observed* per-inference wall time — the
    // modeled cycle figures below are simulator arithmetic, not clock.
    let wall_us = std::sync::Arc::new(openedge_cgra::obs::metrics::Histogram::new());
    let shard = (iters as usize).div_ceil(workers.max(1));
    let jobs: Vec<_> = (0..iters)
        .step_by(shard.max(1))
        .map(|lo| {
            let compiled = compiled.clone();
            let wall_us = wall_us.clone();
            let hi = (lo + shard as u64).min(iters);
            move || -> Result<(u64, u64, f64)> {
                let (mut cycles, mut energy) = (0u64, 0.0f64);
                if batch > 1 {
                    let mut ctx = compiled.new_batch_ctx(batch);
                    let mut i = lo;
                    while i < hi {
                        let n = ((hi - i) as usize).min(batch);
                        let inputs: Vec<_> = (0..n as u64)
                            .map(|j| compiled.net().random_input(8, seed ^ 0xabcd ^ (i + j)))
                            .collect();
                        let t = std::time::Instant::now();
                        let run = if verify {
                            let run = compiled.run_batch_verified(&mut ctx, &inputs)?;
                            if run.exact != Some(true) {
                                anyhow::bail!(
                                    "a batched inference in {i}..{} diverged from the \
                                     generalized golden model",
                                    i + n as u64
                                );
                            }
                            run
                        } else {
                            compiled.run_batch(&mut ctx, &inputs)?
                        };
                        let per_inf_us = t.elapsed().as_micros() as u64 / n as u64;
                        for _ in 0..n {
                            wall_us.record(per_inf_us);
                        }
                        cycles = run.total_cycles;
                        energy = run.total_energy_uj;
                        i += n as u64;
                    }
                } else {
                    let mut ctx = compiled.new_ctx();
                    for i in lo..hi {
                        let input = compiled.net().random_input(8, seed ^ 0xabcd ^ i);
                        let t = std::time::Instant::now();
                        let run = if verify {
                            let run = compiled.run_verified(&mut ctx, &input)?;
                            if run.exact != Some(true) {
                                anyhow::bail!(
                                    "inference {i} diverged from the generalized golden model"
                                );
                            }
                            run
                        } else {
                            compiled.run(&mut ctx, &input)?
                        };
                        wall_us.record(t.elapsed().as_micros() as u64);
                        cycles = run.total_cycles;
                        energy = run.total_energy_uj;
                    }
                }
                Ok((hi - lo, cycles, energy))
            }
        })
        .collect();
    let t1 = std::time::Instant::now();
    let results = openedge_cgra::coordinator::run_jobs(workers, jobs);
    let serve_s = t1.elapsed().as_secs_f64();

    let mut served = 0u64;
    let (mut cycles, mut energy) = (0u64, 0.0f64);
    for r in results {
        let (n, c, e) = r?;
        served += n;
        cycles = c;
        energy = e;
    }
    println!(
        "served {served} inferences in {:.1} ms -> {:.1} inf/s wall \
         ({:.3} ms compile amortized over {served})",
        serve_s * 1e3,
        served as f64 / serve_s.max(1e-9),
        compile_s * 1e3 / served as f64,
    );
    println!("observed wall/inference: {}", wall_us.summary().human("us"));
    println!(
        "modeled per-inference: {cycles} cycles, {energy:.2} uJ \
         (identical to the interpreted path by construction)"
    );
    if verify {
        println!("golden debug-verify: every layer of every inference exact");
    }
    Ok(())
}

/// `cgra daemon` — the persistent serving subsystem: listen for NDJSON
/// requests over TCP and serve them through a multi-tenant
/// [`openedge_cgra::server::Daemon`] — bounded artifact registry,
/// planner-priced admission control with deadlines, a batching worker
/// pool, and a `stats` endpoint. One request object per line; see
/// `openedge_cgra::server::protocol` for the wire format. Runs until a
/// `{"op":"shutdown"}` request arrives, then drains in-flight work and
/// prints a final stats summary.
fn cmd_daemon() -> Result<()> {
    let a = Args::from_env(
        2,
        &["profile"],
        vec![
            OptSpec { name: "port", value: "INT", help: "TCP port (default 0 = OS-assigned)" },
            OptSpec { name: "workers", value: "INT", help: "worker threads (default 2)" },
            OptSpec {
                name: "batch",
                value: "INT",
                help: "max inference lanes per shared uop walk (default 4; 1 = scalar)",
            },
            OptSpec {
                name: "capacity",
                value: "INT",
                help: "artifact-registry capacity (default 32)",
            },
            OptSpec {
                name: "admission",
                value: "reject|degrade",
                help: "deadline policy: reject outright, or degrade \
                       (latency-remap, then batch-1) before rejecting (default degrade)",
            },
            OptSpec {
                name: "profile",
                value: "",
                help: "attribute walk cycles to bottleneck classes; per-tenant aggregates \
                       appear under 'bottleneck' in stats (off = zero overhead)",
            },
            OptSpec {
                name: "artifact-dir",
                value: "DIR",
                help: "disk-backed registry tier: load serialized artifacts from (and \
                       persist fresh compiles to) this directory across restarts",
            },
        ],
    )?;
    let port: u16 = a.num_or("port", 0u16)?;
    let workers = a.num_or("workers", 2usize)?;
    let batch = a.num_or("batch", 4usize)?;
    let capacity = a.num_or("capacity", 32usize)?;
    let policy =
        openedge_cgra::server::AdmissionPolicy::parse(&a.str_or("admission", "degrade"))?;
    let profiling = a.flag("profile");
    let artifact_dir = a.opt_str("artifact-dir").map(str::to_string);
    a.reject_unknown()?;
    if let Some(dir) = &artifact_dir {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating artifact directory {dir}"))?;
    }
    // Held for the daemon's lifetime: flips the profiler on so worker
    // runs carry per-inference bottleneck deltas into tenant counters.
    let _psession = profiling.then(openedge_cgra::obs::profile::session);

    let mut builder = openedge_cgra::server::Daemon::builder()
        .workers(workers)
        .batch(batch)
        .capacity(capacity)
        .admission(policy);
    if let Some(dir) = &artifact_dir {
        builder = builder.artifact_dir(dir);
    }
    let daemon = std::sync::Arc::new(builder.build());
    let listener = std::net::TcpListener::bind(("127.0.0.1", port))
        .with_context(|| format!("binding 127.0.0.1:{port}"))?;
    let addr = listener.local_addr()?;
    println!(
        "daemon listening on {addr} ({} workers, batch {}, registry capacity {}, \
         admission {})",
        daemon.workers(),
        daemon.batch(),
        daemon.registry().stats().capacity,
        policy.label(),
    );
    if profiling {
        println!("bottleneck profiler: on (per-tenant 'bottleneck' aggregates in stats)");
    }
    if let Some(dir) = &artifact_dir {
        println!("artifact disk tier: {dir} (compiles persist; restarts load, zero rebuilds)");
    }
    // The smoke script scrapes the line above from a pipe — make sure
    // it is visible before the first connection is accepted.
    use std::io::Write as _;
    std::io::stdout().flush().ok();

    openedge_cgra::server::tcp::serve(daemon.clone(), listener)?;

    let stats = daemon.stats();
    println!(
        "daemon stopped after {:.1} s: served {} requests / {} inferences \
         ({:.1} inf/s), rejected {}, degraded {}; registry {} hits / {} misses / \
         {} evictions / {} compiles / {} disk hits / {} disk writes; \
         {} walks over {} lanes",
        stats.uptime_s,
        stats.served_requests,
        stats.served_inferences,
        stats.throughput_inf_per_s(),
        stats.rejected,
        stats.degraded,
        stats.registry.hits,
        stats.registry.misses,
        stats.registry.evictions,
        stats.registry.compiles,
        stats.registry.disk_hits,
        stats.registry.disk_writes,
        stats.walks,
        stats.walk_lanes,
    );
    if stats.e2e_us.count > 0 {
        println!("  observed e2e latency/request: {}", stats.e2e_us.human("us"));
        println!("  observed queue wait/job:      {}", stats.queue_wait_us.human("us"));
    }
    for t in &stats.tenants {
        let c = t.counters;
        println!(
            "  tenant '{}' [{:#018x}]: {} req / {} inf, priced {:.2} uJ vs run {:.2} uJ",
            t.name, t.session_fp, c.requests, c.inferences, c.priced_uj, c.run_uj
        );
    }
    Ok(())
}

/// `cgra trace` — run compiled inferences under the span tracer and
/// export a Chrome trace-event file (`chrome://tracing` / Perfetto).
/// The trace nests one span per inference, per layer, per kernel and
/// per µop-walk launch, with per-launch cycles attributed to the
/// paper's Figure-3 op classes. A per-layer modeled-cycle breakdown
/// table is printed alongside.
fn cmd_trace() -> Result<()> {
    let a = Args::from_env(
        2,
        &[],
        vec![
            OptSpec {
                name: "preset",
                value: "NAME",
                help: "named network: mobilenet-mini | paper-baseline | vgg-mini \
                       (default: a plain --depth/--c0/--k/--hw conv stack)",
            },
            OptSpec { name: "iters", value: "INT", help: "traced inferences (default 3)" },
            OptSpec {
                name: "out",
                value: "FILE",
                help: "Chrome trace-event output path (default trace.json)",
            },
            OptSpec { name: "depth", value: "INT", help: "plain stack: conv layers" },
            OptSpec { name: "c0", value: "INT", help: "plain stack: input channels" },
            OptSpec { name: "k", value: "INT", help: "plain stack: channels per layer" },
            OptSpec { name: "hw", value: "INT", help: "plain stack: input height=width" },
            OptSpec { name: "seed", value: "INT", help: "weight/data seed" },
        ],
    )?;
    let seed = a.num_or("seed", 7u64)?;
    let iters: u64 = a.num_or("iters", 3u64)?;
    let out = a.str_or("out", "trace.json");
    let net = net_from_args(&a, seed)?;
    a.reject_unknown()?;
    anyhow::ensure!(iters >= 1, "--iters must be at least 1");

    let engine = EngineBuilder::new().build()?;
    let compiled = engine.compile_owned(net)?;
    let mut ctx = compiled.new_ctx();
    // One warm-up run outside the session: the trace shows the serving
    // steady state, not first-touch effects.
    let input = compiled.net().random_input(8, seed ^ 0xabcd);
    compiled.run(&mut ctx, &input)?;

    let session = openedge_cgra::obs::trace::session();
    let mut last = None;
    for i in 0..iters {
        let input = compiled.net().random_input(8, seed ^ 0xabcd ^ i);
        last = Some(compiled.run(&mut ctx, &input)?);
    }
    let trace = session.finish();
    let run = last.expect("at least one traced inference");

    let mut table = openedge_cgra::util::fmt::Table::new(&[
        "layer", "kind", "mapping", "cycles", "conv", "host", "relu", "launches",
    ]);
    for (i, l) in run.layers.iter().enumerate() {
        let info = compiled.layer_info(i);
        table.row(vec![
            i.to_string(),
            info.kind.into(),
            l.mapping.map(|m| m.label().to_string()).unwrap_or_else(|| "host".into()),
            l.cycles.to_string(),
            l.conv_cycles.to_string(),
            l.host_cycles.to_string(),
            l.relu_cycles.to_string(),
            l.launches.to_string(),
        ]);
    }
    println!(
        "traced {iters} inferences of '{}' ({} layers, {} modeled cycles/inference)\n",
        compiled.name(),
        compiled.layer_count(),
        run.total_cycles
    );
    print!("{}", table.render());

    std::fs::write(&out, trace.to_chrome_json().to_string_pretty())
        .with_context(|| format!("writing {out}"))?;
    if trace.dropped > 0 {
        eprintln!(
            "warning: trace buffer full — {} event(s) dropped and missing from {out}; \
             lower --iters or trace a smaller network (the export carries a \
             'trace_buffer_dropped' metadata event with the count)",
            trace.dropped
        );
    }
    println!(
        "\nwrote {} spans to {out} ({} dropped); open in chrome://tracing or Perfetto",
        trace.events.len(),
        trace.dropped
    );
    Ok(())
}

/// `cgra profile` — run inferences under the cycle-attribution profiler
/// (DESIGN.md §12) and print a roofline-style bottleneck report: every
/// simulated step's cycles attributed to alu / dma-port / bank-conflict
/// / control / watchdog-floor, per-PE busy occupancy on the 4x4 grid,
/// per-bank conflict histograms, and the memory high-water mark.
/// Profiling is observe-only: modeled cycles and energy are
/// bit-identical to an unprofiled run.
///
/// Two modes: a compiled network (`--preset` / plain-stack options,
/// aggregates walk → layer → network), or a single convolution layer
/// (`--mapping` + `--shape`). `--out FILE.json` writes the full JSON
/// aggregate plus `<stem>.pe_ops.csv` (per-PE × op-class heatmap) and
/// `<stem>.banks.csv` (per-bank conflict-degree heatmap).
fn cmd_profile() -> Result<()> {
    let a = Args::from_env(
        2,
        &[],
        vec![
            OptSpec {
                name: "preset",
                value: "NAME",
                help: "named network: mobilenet-mini | paper-baseline | vgg-mini \
                       (default: a plain --depth/--c0/--k/--hw conv stack)",
            },
            OptSpec {
                name: "mapping",
                value: "wp|ip|im2col-op|conv-op|dw",
                help: "single-layer mode: profile one convolution with this strategy \
                       instead of a compiled network",
            },
            OptSpec {
                name: "shape",
                value: "CxKxOXxOY",
                help: "single-layer mode: conv shape (default 16x16x16x16)",
            },
            OptSpec { name: "iters", value: "INT", help: "profiled inferences (default 3)" },
            OptSpec {
                name: "out",
                value: "FILE",
                help: "JSON output path; also writes <stem>.pe_ops.csv and <stem>.banks.csv",
            },
            OptSpec { name: "depth", value: "INT", help: "plain stack: conv layers" },
            OptSpec { name: "c0", value: "INT", help: "plain stack: input channels" },
            OptSpec { name: "k", value: "INT", help: "plain stack: channels per layer" },
            OptSpec { name: "hw", value: "INT", help: "plain stack: input height=width" },
            OptSpec { name: "seed", value: "INT", help: "weight/data seed" },
        ],
    )?;
    let seed = a.num_or("seed", 7u64)?;
    let iters: u64 = a.num_or("iters", 3u64)?;
    let out = a.opt_str("out").map(str::to_string);
    let single = a.opt_str("mapping").map(str::to_string);
    let shape_s = a.str_or("shape", "16x16x16x16");
    anyhow::ensure!(iters >= 1, "--iters must be at least 1");

    if let Some(m) = single {
        // Single-layer mode: profile one convolution. Explicit tensors
        // keep the engine's result cache out of the loop, so every
        // iteration is a real simulation.
        let mapping = Mapping::parse(&m)?;
        let dims: Vec<usize> = shape_s.split('x').filter_map(|t| t.parse().ok()).collect();
        anyhow::ensure!(
            dims.len() == 4 && shape_s.split('x').count() == 4,
            "--shape must be CxKxOXxOY, got '{shape_s}'"
        );
        let shape = ConvShape::checked(dims[0], dims[1], dims[2], dims[3])?;
        a.reject_unknown()?;
        let engine = EngineBuilder::new().build()?;
        let mut rng = Rng::new(seed);
        let input = random_input(&shape, 30, &mut rng);
        let weights = if mapping == Mapping::DwWp {
            anyhow::ensure!(
                shape.k == shape.c,
                "depthwise convention: K must equal C, got K={} C={}",
                shape.k,
                shape.c
            );
            openedge_cgra::conv::random_depthwise_weights(&shape, 9, &mut rng)
        } else {
            random_weights(&shape, 9, &mut rng)
        };
        let session = openedge_cgra::obs::profile::session();
        let mut cycles = 0u64;
        for _ in 0..iters {
            let res = engine.submit(&ConvRequest::with_data(
                shape,
                mapping,
                input.clone(),
                weights.clone(),
            ))?;
            cycles = res.report.latency_cycles;
        }
        let prof = session.finish();
        println!(
            "profiled {iters} runs of {} on layer {shape} ({cycles} modeled cycles/run)\n",
            mapping.label()
        );
        render_profile(&prof, out.as_deref())?;
        return Ok(());
    }

    // Network mode: compile and warm up OUTSIDE the session — auto
    // decisions simulate planner probe launches at compile time, and
    // those must not pollute the serving-steady-state attribution.
    let net = net_from_args(&a, seed)?;
    a.reject_unknown()?;
    let engine = EngineBuilder::new().build()?;
    let compiled = engine.compile_owned(net)?;
    let mut ctx = compiled.new_ctx();
    let input = compiled.net().random_input(8, seed ^ 0xabcd);
    compiled.run(&mut ctx, &input)?;

    let session = openedge_cgra::obs::profile::session();
    let mut last = None;
    for i in 0..iters {
        let input = compiled.net().random_input(8, seed ^ 0xabcd ^ i);
        last = Some(compiled.run(&mut ctx, &input)?);
    }
    let prof = session.finish();
    let run = last.expect("at least one profiled inference");
    println!(
        "profiled {iters} inferences of '{}' ({} layers, {} modeled cycles/inference)\n",
        compiled.name(),
        compiled.layer_count(),
        run.total_cycles
    );
    if let Some(d) = &run.profile {
        let attributed: u64 = d.class_cycles.iter().sum();
        println!(
            "per-inference walk attribution: {} cycles over {} walks \
             (sums exactly: {})\n",
            d.cycles,
            d.walks,
            if attributed == d.cycles { "yes" } else { "NO" },
        );
    }
    render_profile(&prof, out.as_deref())?;
    Ok(())
}

/// Print the roofline-style text report for a finished profile and
/// write the JSON + CSV artifacts when an output path was given.
fn render_profile(prof: &openedge_cgra::obs::Profile, out: Option<&str>) -> Result<()> {
    use openedge_cgra::isa::{COLS, N_PES, ROWS};
    use openedge_cgra::obs::BnClass;

    let t = &prof.total;
    println!(
        "bottleneck attribution ({} walk cycles, {} walks, {} steps):",
        t.cycles, t.walks, t.steps
    );
    let shares = t.class_shares();
    for b in BnClass::ALL {
        let pct = shares[b.idx()] * 100.0;
        let bar = "#".repeat((pct * 0.28).round() as usize);
        println!(
            "  {:<14} {:<28} {:5.1}%  ({} cycles)",
            b.label(),
            bar,
            pct,
            t.class_cycles[b.idx()]
        );
    }

    println!("\nper-PE busy occupancy ({ROWS}x{COLS} grid, % of walk cycles):");
    for r in 0..ROWS {
        let row: Vec<String> = (0..COLS)
            .map(|c| {
                let i = r * COLS + c;
                let total = t.busy[i] + t.idle[i];
                if total == 0 {
                    "    -".into()
                } else {
                    format!("{:5.1}", 100.0 * t.busy[i] as f64 / total as f64)
                }
            })
            .collect();
        println!("  {}", row.join(" "));
    }

    let conflicted: Vec<(usize, u64)> = (0..t.bank_conflicts.len())
        .map(|b| (b, t.bank_conflict_steps(b)))
        .filter(|&(_, n)| n > 0)
        .collect();
    if conflicted.is_empty() {
        println!("\nbank conflicts: none");
    } else {
        println!("\nbank conflicts (steps with >= 2 same-bank accesses):");
        for (b, n) in &conflicted {
            let max_d = (2..=openedge_cgra::obs::profile::MAX_CONFLICT_DEGREE)
                .filter(|&d| t.bank_conflicts[*b][d] > 0)
                .max()
                .unwrap_or(0);
            println!("  bank {b:2}: {n} conflicted steps (max degree {max_d})");
        }
    }
    println!(
        "memory high water: {} words ({})",
        t.hi_water_words,
        openedge_cgra::util::fmt::kib(4 * t.hi_water_words)
    );

    let top = |d: &openedge_cgra::obs::ProfileDelta| -> String {
        let shares = d.class_shares();
        BnClass::ALL
            .iter()
            .max_by(|a, b| shares[a.idx()].total_cmp(&shares[b.idx()]))
            .map(|b| format!("{} {:.0}%", b.label(), shares[b.idx()] * 100.0))
            .unwrap_or_default()
    };
    if !prof.by_mapping.is_empty() {
        println!("\nby mapping:");
        for (label, d) in &prof.by_mapping {
            println!(
                "  {label:<10} {:>10} cycles over {:>5} walks — top bottleneck: {}",
                d.cycles,
                d.walks,
                top(d)
            );
        }
    }
    if !prof.by_layer.is_empty() {
        println!("\nby layer:");
        for (key, d) in &prof.by_layer {
            println!(
                "  {key:<14} {:>10} cycles over {:>5} walks — top bottleneck: {}",
                d.cycles,
                d.walks,
                top(d)
            );
        }
    }

    if let Some(out) = out {
        std::fs::write(out, prof.to_json().to_string_pretty())
            .with_context(|| format!("writing {out}"))?;
        let stem = out.strip_suffix(".json").unwrap_or(out);

        let mut pe_csv = String::from("pe,row,col,busy_cycles,idle_cycles");
        for c in openedge_cgra::cgra::OpClass::ALL {
            pe_csv.push(',');
            pe_csv.push_str(c.label());
        }
        pe_csv.push('\n');
        for i in 0..N_PES {
            pe_csv.push_str(&format!(
                "{i},{},{},{},{}",
                i / COLS,
                i % COLS,
                t.busy[i],
                t.idle[i]
            ));
            for c in openedge_cgra::cgra::OpClass::ALL {
                pe_csv.push_str(&format!(",{}", t.pe_ops[i][c.idx()]));
            }
            pe_csv.push('\n');
        }
        let pe_path = format!("{stem}.pe_ops.csv");
        std::fs::write(&pe_path, pe_csv).with_context(|| format!("writing {pe_path}"))?;

        let mut bank_csv = String::from("bank,conflicted_steps");
        for d in 1..=openedge_cgra::obs::profile::MAX_CONFLICT_DEGREE {
            bank_csv.push_str(&format!(",d{d}"));
        }
        bank_csv.push('\n');
        for (b, h) in t.bank_conflicts.iter().enumerate() {
            bank_csv.push_str(&format!("{b},{}", t.bank_conflict_steps(b)));
            for d in 1..=openedge_cgra::obs::profile::MAX_CONFLICT_DEGREE {
                bank_csv.push_str(&format!(",{}", h[d]));
            }
            bank_csv.push('\n');
        }
        let bank_path = format!("{stem}.banks.csv");
        std::fs::write(&bank_path, bank_csv).with_context(|| format!("writing {bank_path}"))?;

        println!("\nwrote {out}, {pe_path}, {bank_path}");
    }
    Ok(())
}

fn cmd_verify() -> Result<()> {
    let a = Args::from_env(
        2,
        &[],
        vec![OptSpec { name: "artifacts", value: "DIR", help: "AOT artifact directory" }],
    )?;
    let dir = a.str_or("artifacts", "artifacts");
    a.reject_unknown()?;
    let summary = openedge_cgra::runtime::verify_all(std::path::Path::new(&dir))?;
    println!("{summary}");
    Ok(())
}

fn cmd_asm() -> Result<()> {
    let path = std::env::args().nth(2).context("usage: cgra asm FILE.casm")?;
    let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path}"))?;
    let prog = openedge_cgra::asm::assemble(&text)?;
    println!("{}", prog.disassemble());
    let engine = EngineBuilder::new().build()?;
    let mut mem = Memory::new(engine.config().mem_words, engine.config().n_banks);
    let stats = engine.cgra().run(&prog, &mut mem)?;
    println!(
        "ran {} steps / {} cycles, utilization {:.1}%, mem {} loads {} stores",
        stats.steps,
        stats.cycles,
        stats.utilization() * 100.0,
        stats.mem.loads,
        stats.mem.stores
    );
    println!("mem[0..16] = {:?}", mem.peek_slice(0, 16));
    Ok(())
}
