//! `cgra` — command-line front end of the OpenEdgeCGRA reproduction.
//! Every subcommand drives one shared [`Engine`] session.
//!
//! ```text
//! cgra run     --mapping wp --c 16 --k 16 --ox 16 --oy 16   one convolution
//! cgra report  fig3|fig4|fig5|all [--out DIR] [--full]      regenerate figures
//! cgra sweep   [--full] [--out DIR]                          Fig. 5 sweep
//! cgra net     [--depth 4] [--k 16] [--hw 32]                CNN on the CGRA
//! cgra verify  [--artifacts DIR]                             CGRA vs XLA artifact
//! cgra asm     FILE.casm                                     assemble + run + dump
//! ```

use anyhow::{bail, Context, Result};

use openedge_cgra::cgra::Memory;
use openedge_cgra::conv::{random_input, random_weights, ConvShape};
use openedge_cgra::coordinator::{default_workers, ConvNet, SweepSpec};
use openedge_cgra::engine::{ConvRequest, Engine, EngineBuilder};
use openedge_cgra::kernels::Mapping;
use openedge_cgra::prop::Rng;
use openedge_cgra::report;
use openedge_cgra::util::{Args, OptSpec};

fn main() {
    if let Err(e) = dispatch() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const USAGE: &str = "usage: cgra <run|report|sweep|net|verify|asm> [options]\n\
                     see README.md for per-command options";

fn dispatch() -> Result<()> {
    let cmd = std::env::args().nth(1).unwrap_or_default();
    match cmd.as_str() {
        "run" => cmd_run(),
        "report" => cmd_report(),
        "sweep" => cmd_sweep(),
        "net" => cmd_net(),
        "verify" => cmd_verify(),
        "asm" => cmd_asm(),
        "" | "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn shape_from(a: &Args) -> Result<ConvShape> {
    Ok(ConvShape::new3x3(
        a.num_or("c", 16usize)?,
        a.num_or("k", 16usize)?,
        a.num_or("ox", 16usize)?,
        a.num_or("oy", 16usize)?,
    ))
}

fn engine_with_workers(workers: usize) -> Result<Engine> {
    EngineBuilder::new().workers(workers).build()
}

fn cmd_run() -> Result<()> {
    let a = Args::from_env(
        2,
        &[],
        vec![
            OptSpec {
                name: "mapping",
                value: "wp|ip|im2col-op|conv-op|cpu|auto|all",
                help: "strategy (auto lets the engine pick)",
            },
            OptSpec { name: "c", value: "INT", help: "input channels" },
            OptSpec { name: "k", value: "INT", help: "output channels" },
            OptSpec { name: "ox", value: "INT", help: "output rows" },
            OptSpec { name: "oy", value: "INT", help: "output cols" },
            OptSpec { name: "seed", value: "INT", help: "data seed" },
            OptSpec { name: "workers", value: "INT", help: "worker threads" },
        ],
    )?;
    let shape = shape_from(&a)?;
    let seed = a.num_or("seed", 42u64)?;
    let which = a.str_or("mapping", "all");
    let workers = a.num_or("workers", default_workers())?;
    a.reject_unknown()?;

    let engine = engine_with_workers(workers)?;
    let mappings: Vec<Mapping> = if which == "all" {
        Mapping::ALL.to_vec()
    } else {
        vec![Mapping::parse(&which)?]
    };

    // Explicit tensors keep the golden check honest: these requests are
    // never served from the cache, so "exact" always reflects a real
    // simulation.
    let mut rng = Rng::new(seed);
    let input = random_input(&shape, 30, &mut rng);
    let weights = random_weights(&shape, 9, &mut rng);
    let golden = openedge_cgra::conv::conv2d(&shape, &input, &weights);
    let reqs: Vec<ConvRequest> = mappings
        .iter()
        .map(|&m| ConvRequest::with_data(shape, m, input.clone(), weights.clone()))
        .collect();

    println!("layer {shape}  ({} MACs)\n", shape.macs());
    let mut table = openedge_cgra::util::fmt::Table::new(&[
        "mapping", "cycles", "MAC/cycle", "energy_uJ", "power_mW", "memory", "exact",
    ]);
    let mut decisions = Vec::new();
    let mut failures: Vec<(Mapping, anyhow::Error)> = Vec::new();
    for (&m, res) in mappings.iter().zip(engine.submit_batch(&reqs)) {
        match res {
            Ok(res) => {
                let exact = res.output.data == golden.data;
                let r = &res.report;
                table.row(vec![
                    res.mapping.label().into(),
                    r.latency_cycles.to_string(),
                    format!("{:.3}", r.mac_per_cycle),
                    format!("{:.2}", r.energy_uj),
                    format!("{:.2}", r.avg_power_mw),
                    openedge_cgra::util::fmt::kib(r.footprint_bytes),
                    if exact { "yes".into() } else { "NO".into() },
                ]);
                if let Some(d) = res.auto {
                    decisions.push(d);
                }
            }
            // Per-mapping failures (e.g. the 512 KiB bound) keep their
            // row and never discard the completed mappings.
            Err(e) => {
                table.row(vec![
                    m.label().into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "skipped".into(),
                ]);
                failures.push((m, e));
            }
        }
    }
    print!("{}", table.render());
    for d in decisions {
        println!("{d}");
    }
    for (m, e) in &failures {
        println!("{}: skipped — {e:#}", m.label());
    }
    if failures.len() == mappings.len() {
        bail!("every requested mapping failed");
    }
    Ok(())
}

fn cmd_report() -> Result<()> {
    let a = Args::from_env(
        3,
        &["full"],
        vec![
            OptSpec { name: "out", value: "DIR", help: "directory for .txt/.csv output" },
            OptSpec { name: "full", value: "", help: "full paper sweep for fig5 (slow)" },
            OptSpec { name: "workers", value: "INT", help: "worker threads" },
        ],
    )?;
    let which = std::env::args().nth(2).unwrap_or_else(|| "all".into());
    let workers = a.num_or("workers", default_workers())?;
    let full = a.flag("full");
    let out_dir = a.opt_str("out").map(std::path::PathBuf::from);
    a.reject_unknown()?;

    let engine = engine_with_workers(workers)?;
    let spec = if full { SweepSpec::paper() } else { SweepSpec::quick() };
    let figures: Vec<report::Figure> = match which.as_str() {
        "fig3" => vec![report::fig3(&engine)?],
        "fig4" => vec![report::fig4(&engine)?],
        "fig5" => vec![report::fig5(&engine, &spec)?],
        "all" => vec![
            report::fig3(&engine)?,
            report::fig4(&engine)?,
            report::fig5(&engine, &spec)?,
        ],
        other => bail!("unknown figure '{other}' (fig3|fig4|fig5|all)"),
    };
    for f in &figures {
        println!("{}\n", f.text);
        if let Some(dir) = &out_dir {
            f.save(dir)?;
            println!("saved {}/{}.{{txt,csv}}", dir.display(), f.id);
        }
    }
    Ok(())
}

fn cmd_sweep() -> Result<()> {
    let a = Args::from_env(
        2,
        &["full"],
        vec![
            OptSpec { name: "full", value: "", help: "full paper grid (slow)" },
            OptSpec { name: "out", value: "DIR", help: "output directory" },
            OptSpec { name: "workers", value: "INT", help: "worker threads" },
        ],
    )?;
    let workers = a.num_or("workers", default_workers())?;
    let spec = if a.flag("full") { SweepSpec::paper() } else { SweepSpec::quick() };
    let out_dir = a.opt_str("out").map(std::path::PathBuf::from);
    a.reject_unknown()?;
    let engine = engine_with_workers(workers)?;
    let f = report::fig5(&engine, &spec)?;
    println!("{}", f.text);
    if let Some(dir) = out_dir {
        f.save(&dir)?;
    }
    Ok(())
}

fn cmd_net() -> Result<()> {
    let a = Args::from_env(
        2,
        &[],
        vec![
            OptSpec { name: "depth", value: "INT", help: "number of conv layers" },
            OptSpec { name: "c0", value: "INT", help: "input channels" },
            OptSpec { name: "k", value: "INT", help: "channels per layer" },
            OptSpec { name: "hw", value: "INT", help: "input height=width" },
            OptSpec { name: "seed", value: "INT", help: "weight/data seed" },
        ],
    )?;
    let depth = a.num_or("depth", 4usize)?;
    let c0 = a.num_or("c0", 3usize)?;
    let k = a.num_or("k", 16usize)?;
    let hw = a.num_or("hw", 32usize)?;
    let seed = a.num_or("seed", 7u64)?;
    a.reject_unknown()?;

    let net = ConvNet::random(depth, c0, k, hw, hw, seed);
    let mut rng = Rng::new(seed ^ 0xabcd);
    let input = random_input(&net.layers[0].shape, 8, &mut rng);
    let engine = EngineBuilder::new().build()?;
    let out = engine.run_network(&net, &input)?;
    let golden = openedge_cgra::coordinator::golden_network(&net, &input)?;
    println!("CNN: {depth} conv layers, {} MACs, input {c0}x{hw}x{hw}", net.macs());
    let mut table = openedge_cgra::util::fmt::Table::new(&[
        "layer", "shape", "mapping", "cycles", "MAC/cycle", "energy_uJ",
    ]);
    for (i, (l, r)) in net.layers.iter().zip(out.layers.iter()).enumerate() {
        table.row(vec![
            i.to_string(),
            l.shape.id(),
            r.mapping.label().into(),
            r.latency_cycles.to_string(),
            format!("{:.3}", r.mac_per_cycle),
            format!("{:.2}", r.energy_uj),
        ]);
    }
    print!("{}", table.render());
    println!(
        "\ntotal: {} cycles ({:.3} MAC/cycle), {:.2} uJ, output exact vs golden: {}",
        out.total_cycles,
        out.mac_per_cycle(&net),
        out.total_energy_uj,
        out.output.data == golden.data
    );
    Ok(())
}

fn cmd_verify() -> Result<()> {
    let a = Args::from_env(
        2,
        &[],
        vec![OptSpec { name: "artifacts", value: "DIR", help: "AOT artifact directory" }],
    )?;
    let dir = a.str_or("artifacts", "artifacts");
    a.reject_unknown()?;
    let summary = openedge_cgra::runtime::verify_all(std::path::Path::new(&dir))?;
    println!("{summary}");
    Ok(())
}

fn cmd_asm() -> Result<()> {
    let path = std::env::args().nth(2).context("usage: cgra asm FILE.casm")?;
    let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path}"))?;
    let prog = openedge_cgra::asm::assemble(&text)?;
    println!("{}", prog.disassemble());
    let engine = EngineBuilder::new().build()?;
    let mut mem = Memory::new(engine.config().mem_words, engine.config().n_banks);
    let stats = engine.cgra().run(&prog, &mut mem)?;
    println!(
        "ran {} steps / {} cycles, utilization {:.1}%, mem {} loads {} stores",
        stats.steps,
        stats.cycles,
        stats.utilization() * 100.0,
        stats.mem.loads,
        stats.mem.stores
    );
    println!("mem[0..16] = {:?}", mem.peek_slice(0, 16));
    Ok(())
}
