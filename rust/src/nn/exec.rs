//! The graph executor: run a [`Net`] end to end on the simulated CGRA
//! through an [`Engine`] session.
//!
//! Every conv-like layer is lowered (`nn::lower`) onto stride-1 / valid
//! engine convolutions — the planner-backed `Mapping::Auto` picks the
//! strategy per layer unless the layer pins one — with the host glue
//! (padding, group slicing, decimation, pooling, fused ReLU) charged by
//! the shared closed-form cost model. Grouped layers fan their
//! independent per-group convolutions over the engine's worker pool as
//! one batch; activations thread through the chain by move, never by
//! clone. Each layer's output is checked element-exactly against the
//! generalized golden model.

use anyhow::{Context, Result};

use crate::conv::{TensorChw, Weights};
use crate::engine::{relu_cost, ConvRequest, Engine};
use crate::kernels::Mapping;

use super::graph::{golden_layer, relu_in_place, Layer, Net};
use super::lower::{
    avgpool2d, concat_channels, decimate, embed_pointwise_weights, host_energy_uj, lower_conv,
    maxpool2d, pad_input, slice_channels, HostOp,
};

/// Everything one executed layer reports.
#[derive(Clone, Debug)]
pub struct LayerReport {
    /// Layer index in execution order.
    pub index: usize,
    /// Layer kind label (`conv` / `depthwise` / `pointwise` / …).
    pub kind: &'static str,
    /// Short shape description.
    pub desc: String,
    /// The concrete strategy that ran on the CGRA (`None` for
    /// host-only pooling layers).
    pub mapping: Option<Mapping>,
    /// End-to-end layer cycles: CGRA convolution + host glue + ReLU.
    pub cycles: u64,
    /// The CGRA convolution part (summed over group submissions).
    pub conv_cycles: u64,
    /// Host glue cycles (pad / slice / decimate / pool / ReLU).
    pub host_cycles: u64,
    /// Layer energy, µJ (convolution + glue + ReLU).
    pub energy_uj: f64,
    /// CGRA launches of the layer.
    pub launches: u64,
    /// True (logical) MACs of the layer.
    pub macs: u64,
    /// Scalar-CPU baseline cycles of the logical layer (0 for pools).
    pub cpu_cycles: u64,
    /// Whether the output matched the generalized golden model
    /// element-exactly.
    pub exact: bool,
}

impl LayerReport {
    /// Speedup of the executed layer over the scalar-CPU baseline
    /// (`None` for host-only layers).
    pub fn speedup(&self) -> Option<f64> {
        (self.cpu_cycles > 0).then(|| self.cpu_cycles as f64 / self.cycles.max(1) as f64)
    }
}

/// The whole-network execution report.
#[derive(Clone, Debug)]
pub struct NetworkReport {
    /// Network name.
    pub name: String,
    /// Per-layer rows, in execution order.
    pub layers: Vec<LayerReport>,
    /// End-to-end cycles.
    pub total_cycles: u64,
    /// End-to-end energy, µJ.
    pub total_energy_uj: f64,
    /// Final activation tensor.
    pub output: TensorChw,
    /// Whether every layer matched the golden model.
    pub exact: bool,
}

impl NetworkReport {
    /// Aggregate MAC/cycle over the true MACs.
    pub fn mac_per_cycle(&self) -> f64 {
        let macs: u64 = self.layers.iter().map(|l| l.macs).sum();
        macs as f64 / self.total_cycles.max(1) as f64
    }

    /// Whole-network speedup over the scalar-CPU baseline. The CPU side
    /// pays the scalar conv cost per conv layer and the *same* cycles
    /// as the executed run for host-only layers (pooling runs on the
    /// host either way); the CGRA lowering's glue (pad / decimate /
    /// shuffle / embed) is charged to the CGRA side only — a scalar CPU
    /// convolves strided/padded/1×1 layers directly.
    pub fn speedup(&self) -> f64 {
        let cpu: u64 = self
            .layers
            .iter()
            .map(|l| if l.cpu_cycles > 0 { l.cpu_cycles } else { l.cycles })
            .sum();
        cpu as f64 / self.total_cycles.max(1) as f64
    }
}

/// Weight bank of a conv-like layer, with the pointwise embedding
/// applied when the lowering asks for it.
fn effective_weights<'a>(
    layer: &'a Layer,
    embed: bool,
    host: &mut HostOp,
) -> std::borrow::Cow<'a, Weights> {
    let w = match layer {
        Layer::Conv { weights, .. }
        | Layer::Depthwise { weights, .. }
        | Layer::Pointwise { weights, .. } => weights,
        _ => unreachable!("effective_weights is only called for conv-like layers"),
    };
    if embed {
        let (e, op) = embed_pointwise_weights(w);
        host.add(op);
        std::borrow::Cow::Owned(e)
    } else {
        std::borrow::Cow::Borrowed(w)
    }
}

/// Execute `net` on the engine. The returned report carries per-layer
/// metrics, golden-exactness flags and the final activation.
pub fn run_network(engine: &Engine, net: &Net, input: &TensorChw) -> Result<NetworkReport> {
    net.validate()?;
    let model = *engine.energy_model();

    // The golden chain advances lazily alongside the executed chain, so
    // a layer that fails (e.g. past the memory bound) costs no golden
    // compute.
    let mut golden_x = input.clone();
    let mut x = input.clone();
    let mut layers = Vec::with_capacity(net.layers.len());
    let mut total_cycles = 0u64;
    let mut total_energy = 0.0f64;
    for (index, layer) in net.layers.iter().enumerate() {
        let ctx = || format!("layer {index} ({}) of '{}'", layer.kind(), net.name);
        let mut host = HostOp::default();
        let mut conv_cycles = 0u64;
        let mut conv_energy = 0.0f64;
        let mut launches = 0u64;
        let mut mapping: Option<Mapping> = None;

        let mut out = match layer {
            Layer::MaxPool { size, stride } => {
                let (out, op) = maxpool2d(&x, *size, *stride);
                host.add(op);
                out
            }
            Layer::AvgPool { size, stride } => {
                let (out, op) = avgpool2d(&x, *size, *stride);
                host.add(op);
                out
            }
            conv_like => {
                let shape = conv_like.conv_shape().expect("conv-like layer has a shape");
                let depthwise = matches!(conv_like, Layer::Depthwise { .. });
                let layer_mapping = match conv_like {
                    Layer::Conv { mapping, .. } | Layer::Pointwise { mapping, .. } => *mapping,
                    _ => Mapping::Auto,
                };
                let lc = lower_conv(shape, layer_mapping, depthwise).with_context(ctx)?;
                // 1. Host padding (layer pad + pointwise ring). When no
                //    padding is needed the activation moves in unchanged.
                let conv_in = if lc.host_pad > 0 {
                    let (p, op) = pad_input(&x, lc.host_pad);
                    host.add(op);
                    p
                } else {
                    std::mem::replace(&mut x, TensorChw::zeros(0, 0, 0))
                };
                // 2. Weights (pointwise banks are center-embedded).
                let w_eff = effective_weights(conv_like, lc.embed_pointwise, &mut host);
                // 3. The engine part: one borrow-based submission, or a
                //    batch of independent per-group convolutions.
                let full = if lc.groups == 1 {
                    let res = engine
                        .run_one(&lc.sub_shape, lc.mapping, false, &conv_in, &w_eff)
                        .with_context(ctx)?;
                    conv_cycles += res.report.latency_cycles;
                    conv_energy += res.report.energy_uj;
                    launches += res.report.launches;
                    mapping = Some(res.mapping);
                    res.output
                } else {
                    let (cg, kg) = (lc.sub_shape.c, lc.sub_shape.k);
                    host.add(super::lower::group_shuffle_cost(
                        conv_in.data.len(),
                        lc.groups * kg * lc.sub_shape.ox * lc.sub_shape.oy,
                    ));
                    let wpg = kg * cg * 9;
                    let reqs: Vec<ConvRequest> = (0..lc.groups)
                        .map(|g| {
                            ConvRequest::with_data(
                                lc.sub_shape,
                                lc.mapping,
                                slice_channels(&conv_in, g * cg, (g + 1) * cg),
                                Weights::from_vec(
                                    kg,
                                    cg,
                                    3,
                                    3,
                                    w_eff.data[g * wpg..(g + 1) * wpg].to_vec(),
                                ),
                            )
                        })
                        .collect();
                    let mut parts = Vec::with_capacity(lc.groups);
                    for (g, res) in engine.submit_batch(&reqs).into_iter().enumerate() {
                        let res = res.with_context(|| format!("group {g}")).with_context(ctx)?;
                        conv_cycles += res.report.latency_cycles;
                        conv_energy += res.report.energy_uj;
                        launches += res.report.launches;
                        mapping = Some(res.mapping);
                        parts.push(res.output);
                    }
                    concat_channels(parts)
                };
                // 4. Stride: decimate the full stride-1 output.
                let (_, ox, oy) = lc.out_dims;
                if lc.stride > 1 {
                    let (d, op) = decimate(&full, lc.stride, ox, oy);
                    host.add(op);
                    d
                } else {
                    full
                }
            }
        };
        // 5. Fused ReLU (host-side, same charge as the engine's).
        let (mut relu_cycles, mut relu_uj) = (0u64, 0.0f64);
        if layer.relu() {
            relu_in_place(&mut out);
            let (c, e) = relu_cost(&model, out.data.len());
            relu_cycles = c;
            relu_uj = e;
        }

        golden_x = golden_layer(layer, &golden_x)?;
        let exact = out.data == golden_x.data;
        let cycles = conv_cycles + host.cycles + relu_cycles;
        let energy_uj = conv_energy + host_energy_uj(&model, host) + relu_uj;
        total_cycles += cycles;
        total_energy += energy_uj;
        layers.push(LayerReport {
            index,
            kind: layer.kind(),
            desc: layer.describe(),
            mapping,
            cycles,
            conv_cycles,
            host_cycles: host.cycles + relu_cycles,
            energy_uj,
            launches,
            macs: layer.macs(),
            cpu_cycles: super::lower::cpu_baseline_cycles(layer),
            exact,
        });
        x = out;
    }

    let exact = layers.iter().all(|l| l.exact);
    Ok(NetworkReport {
        name: net.name.clone(),
        layers,
        total_cycles,
        total_energy_uj: total_energy,
        output: x,
        exact,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineBuilder;
    use crate::prop::Rng;

    fn engine() -> Engine {
        EngineBuilder::new().workers(2).private_cache().build().unwrap()
    }

    /// A network exercising every layer kind executes exactly against
    /// the golden chain, with sensible accounting.
    #[test]
    fn mixed_network_is_exact_and_accounted() {
        let mut rng = Rng::new(9);
        let net = Net {
            name: "mixed".into(),
            input_dims: (2, 10, 10),
            layers: vec![
                Layer::conv(
                    crate::conv::GenConvShape::new(2, 4, 10, 10, 3, 3, 2, 1, 1).unwrap(),
                    true,
                    4,
                    &mut rng,
                )
                .unwrap(), // -> 4x5x5
                Layer::depthwise(4, 5, 5, 1, 1, true, 4, &mut rng).unwrap(), // -> 4x5x5
                Layer::pointwise(4, 8, 5, 5, true, 4, &mut rng).unwrap(), // -> 8x5x5
                Layer::maxpool(2, 2), // -> 8x2x2
            ],
        };
        let input = net.random_input(10, 3);
        let report = run_network(&engine(), &net, &input).unwrap();
        assert!(report.exact, "every layer must match the golden model");
        assert_eq!(report.layers.len(), 4);
        assert_eq!(report.layers[1].mapping, Some(Mapping::DwWp));
        assert_eq!(report.layers[1].launches, 4, "one Dw-WP launch per channel");
        assert!(report.layers[0].host_cycles > 0, "pad + decimate + relu charged");
        assert_eq!(report.layers[3].mapping, None, "pooling is host-only");
        assert_eq!(report.layers[3].conv_cycles, 0);
        assert_eq!(
            report.total_cycles,
            report.layers.iter().map(|l| l.cycles).sum::<u64>()
        );
        assert_eq!((report.output.c, report.output.h, report.output.w), (8, 2, 2));
        // Conv layers report a CPU baseline; the paper's headline says
        // the CGRA should beat it on dense layers.
        assert!(report.layers[0].speedup().is_some());
        assert!(report.layers[3].speedup().is_none());
    }

    /// A grouped conv batches its independent group submissions and
    /// still matches the golden model.
    #[test]
    fn grouped_conv_batches_and_is_exact() {
        let mut rng = Rng::new(11);
        let net = Net {
            name: "grouped".into(),
            input_dims: (4, 8, 8),
            layers: vec![Layer::conv(
                crate::conv::GenConvShape::new(4, 8, 8, 8, 3, 3, 1, 0, 2).unwrap(),
                false,
                4,
                &mut rng,
            )
            .unwrap()],
        };
        let input = net.random_input(12, 7);
        let report = run_network(&engine(), &net, &input).unwrap();
        assert!(report.exact);
        // Two groups of a 2->4 conv: 4*2 launches each under WP.
        assert_eq!(report.layers[0].launches, 2 * 4 * 2);
        assert!(report.layers[0].host_cycles > 0, "group shuffle charged");
    }

    /// The stride-1 fast path submits the layer's exact basic shape —
    /// zero host glue besides the fused ReLU.
    #[test]
    fn plain_stack_has_no_glue_overhead() {
        let net = Net::plain_stack(2, 2, 4, 8, 5).unwrap();
        let input = net.random_input(8, 2);
        let report = run_network(&engine(), &net, &input).unwrap();
        assert!(report.exact);
        // Layer 1 has no ReLU and no generalization: pure conv cycles.
        let last = &report.layers[1];
        assert_eq!(last.host_cycles, 0);
        assert_eq!(last.cycles, last.conv_cycles);
        // Auto resolved to the paper's winner on these shapes.
        assert_eq!(report.layers[0].mapping, Some(Mapping::Wp));
    }

    /// Failures carry the layer context.
    #[test]
    fn layer_errors_are_contextualized() {
        let mut rng = Rng::new(1);
        // A conv too big for the 512 KiB bound (same shape class the
        // engine's oversized-request test uses).
        let net = Net {
            name: "big".into(),
            input_dims: (16, 66, 66),
            layers: vec![Layer::conv(
                crate::conv::GenConvShape::new(16, 16, 66, 66, 3, 3, 1, 0, 1).unwrap(),
                false,
                2,
                &mut rng,
            )
            .unwrap()],
        };
        let input = net.random_input(2, 1);
        let err = format!("{:#}", run_network(&engine(), &net, &input).unwrap_err());
        assert!(err.contains("layer 0") && err.contains("big"), "{err}");
    }
}
