//! The graph executor: run a [`Net`] end to end on the simulated CGRA
//! through an [`Engine`] session.
//!
//! Since the compile-once refactor this is a thin wrapper over the
//! crate's single lowering path: the network is compiled into a
//! [`CompiledNet`] (`engine::compiled`) — planner-resolved mappings,
//! pre-decoded launch programs, frozen layouts, specialized host-op
//! steps — and executed once in the opt-in golden-verified debug mode,
//! preserving the legacy per-layer exactness contract. Callers serving
//! repeated inference traffic should compile once themselves
//! ([`Engine::compile`]) and reuse the artifact: the warm path skips
//! both the compile work and the golden tax.
//!
//! Cycle and energy accounting are unchanged: the compiled steps charge
//! the identical closed-form host-glue and kernel costs the interpreted
//! executor charged (pinned by `tests/compiled.rs`).
//!
//! One deliberate wall-clock trade: grouped layers used to fan their
//! per-group submissions over the engine's worker pool *within* one
//! inference; a compiled context replays them sequentially (one CGRA
//! memory image per context), and parallelism moved *across*
//! inferences instead — share an `Arc<CompiledNet>` and give each
//! worker its own context (`cgra serve --workers N`). Modeled cycles
//! are unaffected (group submissions were always summed).
//!
//! [`CompiledNet`]: crate::engine::CompiledNet
//! [`Engine::compile`]: crate::engine::Engine::compile

use anyhow::Result;

use crate::conv::TensorChw;
use crate::engine::Engine;
use crate::kernels::Mapping;

use super::graph::Net;

/// Everything one executed layer reports.
#[derive(Clone, Debug)]
pub struct LayerReport {
    /// Layer index in execution order.
    pub index: usize,
    /// Layer kind label (`conv` / `depthwise` / `pointwise` / …).
    pub kind: &'static str,
    /// Short shape description.
    pub desc: String,
    /// The concrete strategy that ran on the CGRA (`None` for
    /// host-only pooling layers).
    pub mapping: Option<Mapping>,
    /// End-to-end layer cycles: CGRA convolution + host glue + ReLU.
    pub cycles: u64,
    /// The CGRA convolution part (summed over group submissions).
    pub conv_cycles: u64,
    /// Host glue cycles (pad / slice / decimate / pool / ReLU).
    pub host_cycles: u64,
    /// Layer energy, µJ (convolution + glue + ReLU).
    pub energy_uj: f64,
    /// CGRA launches of the layer.
    pub launches: u64,
    /// True (logical) MACs of the layer.
    pub macs: u64,
    /// Scalar-CPU baseline cycles of the logical layer (0 for pools).
    pub cpu_cycles: u64,
    /// Whether the output matched the generalized golden model
    /// element-exactly.
    pub exact: bool,
}

impl LayerReport {
    /// Speedup of the executed layer over the scalar-CPU baseline
    /// (`None` for host-only layers).
    pub fn speedup(&self) -> Option<f64> {
        (self.cpu_cycles > 0).then(|| self.cpu_cycles as f64 / self.cycles.max(1) as f64)
    }
}

/// The whole-network execution report.
#[derive(Clone, Debug)]
pub struct NetworkReport {
    /// Network name.
    pub name: String,
    /// Per-layer rows, in execution order.
    pub layers: Vec<LayerReport>,
    /// End-to-end cycles.
    pub total_cycles: u64,
    /// End-to-end energy, µJ.
    pub total_energy_uj: f64,
    /// Final activation tensor.
    pub output: TensorChw,
    /// Whether every layer matched the golden model.
    pub exact: bool,
}

impl NetworkReport {
    /// Aggregate MAC/cycle over the true MACs.
    pub fn mac_per_cycle(&self) -> f64 {
        let macs: u64 = self.layers.iter().map(|l| l.macs).sum();
        macs as f64 / self.total_cycles.max(1) as f64
    }

    /// Whole-network speedup over the scalar-CPU baseline. The CPU side
    /// pays the scalar conv cost per conv layer and the *same* cycles
    /// as the executed run for host-only layers (pooling runs on the
    /// host either way); the CGRA lowering's glue (pad / decimate /
    /// shuffle / embed) is charged to the CGRA side only — a scalar CPU
    /// convolves strided/padded/1×1 layers directly.
    pub fn speedup(&self) -> f64 {
        let cpu: u64 = self
            .layers
            .iter()
            .map(|l| if l.cpu_cycles > 0 { l.cpu_cycles } else { l.cycles })
            .sum();
        cpu as f64 / self.total_cycles.max(1) as f64
    }
}

/// Execute `net` on the engine: compile (mappings resolved, programs
/// decoded, arena sized) and run once in golden-verified debug mode.
/// The returned report carries per-layer metrics, golden-exactness
/// flags and the final activation — the same contract as before the
/// compile/run split.
pub fn run_network(engine: &Engine, net: &Net, input: &TensorChw) -> Result<NetworkReport> {
    let compiled = engine.compile(net)?;
    let mut ctx = compiled.new_ctx();
    let run = compiled.run_verified(&mut ctx, input)?;
    let layers = run
        .layers
        .into_iter()
        .enumerate()
        .map(|(index, l)| {
            let info = compiled.layer_info(index);
            LayerReport {
                index,
                kind: info.kind,
                desc: info.desc.to_string(),
                mapping: l.mapping,
                cycles: l.cycles,
                conv_cycles: l.conv_cycles,
                host_cycles: l.host_cycles,
                energy_uj: l.energy_uj,
                launches: l.launches,
                macs: info.macs,
                cpu_cycles: info.cpu_cycles,
                exact: l.exact.expect("verified run flags every layer"),
            }
        })
        .collect();
    Ok(NetworkReport {
        name: net.name.clone(),
        layers,
        total_cycles: run.total_cycles,
        total_energy_uj: run.total_energy_uj,
        output: ctx.output().clone(),
        exact: run.exact.expect("verified run reports exactness"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineBuilder;
    use crate::nn::graph::Layer;
    use crate::prop::Rng;

    fn engine() -> Engine {
        EngineBuilder::new().workers(2).private_cache().build().unwrap()
    }

    /// A network exercising every layer kind executes exactly against
    /// the golden chain, with sensible accounting.
    #[test]
    fn mixed_network_is_exact_and_accounted() {
        let mut rng = Rng::new(9);
        let net = Net {
            name: "mixed".into(),
            input_dims: (2, 10, 10),
            layers: vec![
                Layer::conv(
                    crate::conv::GenConvShape::new(2, 4, 10, 10, 3, 3, 2, 1, 1).unwrap(),
                    true,
                    4,
                    &mut rng,
                )
                .unwrap(), // -> 4x5x5
                Layer::depthwise(4, 5, 5, 1, 1, true, 4, &mut rng).unwrap(), // -> 4x5x5
                Layer::pointwise(4, 8, 5, 5, true, 4, &mut rng).unwrap(), // -> 8x5x5
                Layer::maxpool(2, 2), // -> 8x2x2
            ],
        };
        let input = net.random_input(10, 3);
        let report = run_network(&engine(), &net, &input).unwrap();
        assert!(report.exact, "every layer must match the golden model");
        assert_eq!(report.layers.len(), 4);
        assert_eq!(report.layers[1].mapping, Some(Mapping::DwWp));
        assert_eq!(report.layers[1].launches, 4, "one Dw-WP launch per channel");
        assert!(report.layers[0].host_cycles > 0, "pad + decimate + relu charged");
        assert_eq!(report.layers[3].mapping, None, "pooling is host-only");
        assert_eq!(report.layers[3].conv_cycles, 0);
        assert_eq!(
            report.total_cycles,
            report.layers.iter().map(|l| l.cycles).sum::<u64>()
        );
        assert_eq!((report.output.c, report.output.h, report.output.w), (8, 2, 2));
        // Conv layers report a CPU baseline; the paper's headline says
        // the CGRA should beat it on dense layers.
        assert!(report.layers[0].speedup().is_some());
        assert!(report.layers[3].speedup().is_none());
    }

    /// A grouped conv replays its per-group prebuilt kernels and still
    /// matches the golden model.
    #[test]
    fn grouped_conv_batches_and_is_exact() {
        let mut rng = Rng::new(11);
        let net = Net {
            name: "grouped".into(),
            input_dims: (4, 8, 8),
            layers: vec![Layer::conv(
                crate::conv::GenConvShape::new(4, 8, 8, 8, 3, 3, 1, 0, 2).unwrap(),
                false,
                4,
                &mut rng,
            )
            .unwrap()],
        };
        let input = net.random_input(12, 7);
        let report = run_network(&engine(), &net, &input).unwrap();
        assert!(report.exact);
        // Two groups of a 2->4 conv: 4*2 launches each under WP.
        assert_eq!(report.layers[0].launches, 2 * 4 * 2);
        assert!(report.layers[0].host_cycles > 0, "group shuffle charged");
    }

    /// The stride-1 fast path submits the layer's exact basic shape —
    /// zero host glue besides the fused ReLU.
    #[test]
    fn plain_stack_has_no_glue_overhead() {
        let net = Net::plain_stack(2, 2, 4, 8, 5).unwrap();
        let input = net.random_input(8, 2);
        let report = run_network(&engine(), &net, &input).unwrap();
        assert!(report.exact);
        // Layer 1 has no ReLU and no generalization: pure conv cycles.
        let last = &report.layers[1];
        assert_eq!(last.host_cycles, 0);
        assert_eq!(last.cycles, last.conv_cycles);
        // Auto resolved to the paper's winner on these shapes.
        assert_eq!(report.layers[0].mapping, Some(Mapping::Wp));
    }

    /// Failures carry the layer context.
    #[test]
    fn layer_errors_are_contextualized() {
        let mut rng = Rng::new(1);
        // A conv too big for the 512 KiB bound (same shape class the
        // engine's oversized-request test uses).
        let net = Net {
            name: "big".into(),
            input_dims: (16, 66, 66),
            layers: vec![Layer::conv(
                crate::conv::GenConvShape::new(16, 16, 66, 66, 3, 3, 1, 0, 1).unwrap(),
                false,
                2,
                &mut rng,
            )
            .unwrap()],
        };
        let input = net.random_input(2, 1);
        let err = format!("{:#}", run_network(&engine(), &net, &input).unwrap_err());
        assert!(err.contains("layer 0") && err.contains("big"), "{err}");
    }
}
