//! # The `nn` layer-graph subsystem
//!
//! End-to-end edge networks on the simulated CGRA: a typed layer IR
//! ([`Layer`] — generalized convolutions with stride / padding /
//! groups, depthwise and pointwise convolutions, pooling, fused ReLU),
//! named presets ([`presets`]), and a graph executor ([`run_network`])
//! that lowers every layer onto the existing [`Engine`] with per-layer
//! planner-backed mapping choice.
//!
//! ## Lowering (see [`lower`] for the rules in full)
//!
//! The paper's kernels are stride-1 / valid / groups-1 / 3×3. Each
//! generalized layer becomes host glue around exactly those kernels:
//! padding is materialized by the host; strides decimate the full
//! stride-1 output; groups split into independent convolutions batched
//! over the engine's pool; pointwise filters are center-embedded into
//! 3×3; depthwise layers run the dedicated `Dw-WP` kernel (one
//! WP-machinery launch per channel). A stride-1 / pad-0 / groups-1
//! dense layer lowers to its exact [`crate::conv::ConvShape`] — the
//! untouched fast path with byte-identical cache and planner keys.
//!
//! Every lowering is *exact* (zero taps and decimation commute with the
//! wrapping arithmetic); the executor checks each layer element-exactly
//! against the generalized golden model ([`graph::golden_network`]) and
//! reports the overcompute the glue pays instead of hiding it.
//!
//! ## Planning
//!
//! [`plan_network`] prices a whole network through the analytical
//! planner — same lowered shapes, same closed-form glue costs — so
//! `cgra net --plan-only` predicts end-to-end cycles/energy without
//! simulating, within the planner's validated ≤ 5 % bound.
//!
//! ## One lowering path
//!
//! Since the compile-once refactor (DESIGN.md §8) the lowering glue is
//! resolved exactly once, in [`lower::glue_spec`]: the planner prices
//! it, `Engine::compile` freezes it into a `CompiledNet` step list,
//! and [`run_network`] executes through that compiled artifact in
//! golden-verified debug mode. Serve repeated traffic by compiling
//! once yourself (`cgra serve`, `Engine::compile`).
//!
//! [`Engine`]: crate::engine::Engine

pub mod exec;
pub mod graph;
pub mod lower;
pub mod plan;
pub mod presets;

pub use exec::{run_network, LayerReport, NetworkReport};
pub use graph::{golden_layer, golden_network, Layer, Net};
pub use plan::{plan_network, LayerPlanReport, NetPlan};
pub use presets::{build as build_preset, preset_names, Preset, PRESETS};
