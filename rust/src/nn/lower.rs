//! Lowering rules: how each generalized layer becomes stride-1 / valid
//! 3×3 engine convolutions plus host-side glue, and the closed-form
//! cost model of that glue. [`glue_spec`] resolves both at once and is
//! the crate's single lowering path: the planner (`nn::plan`) prices
//! from it and the compiler (`engine::compiled`) freezes step lists
//! from it, so predicted and executed host costs are identical by
//! construction.
//!
//! Every glue op has one allocation-free core ([`pad_into`],
//! [`decimate_into`], [`pool_into`]) used directly by the compiled
//! runner (`engine::compiled`) against its pre-sized arena; the
//! allocating forms here ([`pad_input`], [`decimate`], [`maxpool2d`],
//! [`avgpool2d`]) are thin allocate-then-fill wrappers over the same
//! cores, so the reference and serving paths cannot diverge. Each op's
//! cost function (`pad_cost`, `decimate_cost`, …) is the one charge
//! both sides use.
//!
//! # The rules
//!
//! - **Padding `p`** — the host materializes the zero border
//!   (`pad_input`); the engine then runs a *valid* convolution, exactly
//!   as the kernels expect. Charged per copied element like the im2col
//!   preparation the paper overlaps (§2.3).
//! - **Stride `s > 1`** — the engine computes the full stride-1 output
//!   and the host decimates it (`decimate`), keeping every `s`-th pixel
//!   per axis. Exact (a strided conv *is* the stride-1 conv sampled —
//!   pinned in `conv::golden`), at the cost of ~`s²` overcompute on the
//!   CGRA; the per-layer report makes that overcompute visible instead
//!   of hiding it. A strided 3×3 cannot decompose onto kernels that are
//!   hard-wired to 3×3 taps, so this is the honest lowering.
//! - **Groups `g`** — the layer splits into `g` independent
//!   convolutions over contiguous channel slices (CHW keeps channel
//!   ranges contiguous); the executor submits them as one batch over
//!   the engine's worker pool.
//! - **Depthwise** — a single `Dw-WP` submission (`kernels::dw`); no
//!   group split, one launch per channel inside the kernel.
//! - **Pointwise (1×1)** — lowered to a 3×3 with the filter embedded at
//!   the center tap and one extra zero ring of padding: zero taps
//!   contribute nothing (wrapping multiply by 0 is 0), so the result is
//!   exact with 9× tap overcompute, again reported rather than hidden.
//! - **Pooling** — host-side ops with a documented per-element cycle
//!   cost ([`maxpool2d`], [`avgpool2d`]); the paper's system runs
//!   pooling on the MCU too.

use anyhow::{ensure, Result};

use crate::conv::{ConvShape, GenConvShape, TensorChw, Weights};
use crate::cpu_ref::CpuModel;
use crate::energy::EnergyModel;
use crate::kernels::{HostCostModel, Mapping};

use super::graph::Layer;

/// Cycles/accesses of one host-side glue operation (pad, slice,
/// decimate, concat, pool). Energy follows from the session model via
/// [`host_energy_uj`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HostOp {
    /// CPU cycles charged.
    pub cycles: u64,
    /// Memory accesses charged (reads + writes).
    pub accesses: u64,
}

impl HostOp {
    /// Accumulate another op.
    pub fn add(&mut self, other: HostOp) {
        self.cycles += other.cycles;
        self.accesses += other.accesses;
    }
}

/// Energy of a host op, µJ: CPU-active + memory-static power over its
/// duration plus per-access dynamic energy — the same integration the
/// engine's ReLU charge uses, so every host-side cycle in the system is
/// priced identically.
pub fn host_energy_uj(model: &EnergyModel, op: HostOp) -> f64 {
    let t_s = op.cycles as f64 / model.clock_hz;
    (model.p_cpu_active_mw + model.p_mem_static_mw) * t_s * 1e3
        + op.accesses as f64 * model.e_mem_access_pj * 1e-6
}

/// Cycles per element copied/compared by host glue loops (load +
/// store/compare + address bookkeeping on the in-order RV32 core) —
/// the same figure the im2col driver charges.
fn cycles_per_elem() -> u64 {
    HostCostModel::default().im2col_cycles_per_elem
}

/// Allocation-free core of [`pad_input`]: zero-pad a CHW activation by
/// `p` per side into `dst` (already sized to `c·(h+2p)·(w+2p)`). The
/// compiled runner calls this against its arena.
pub fn pad_into(src: &[i32], (c, h, w): (usize, usize, usize), p: usize, dst: &mut [i32]) {
    let (ph, pw) = (h + 2 * p, w + 2 * p);
    debug_assert_eq!(dst.len(), c * ph * pw);
    dst.fill(0);
    for ci in 0..c {
        for y in 0..h {
            let s = (ci * h + y) * w;
            let d = (ci * ph + y + p) * pw + p;
            dst[d..d + w].copy_from_slice(&src[s..s + w]);
        }
    }
}

/// Zero-pad a CHW tensor by `p` on every spatial side. Returns the
/// padded tensor and the host charge (one pass over the padded tensor:
/// every destination element is written, interior elements are read
/// from the source).
pub fn pad_input(x: &TensorChw, p: usize) -> (TensorChw, HostOp) {
    if p == 0 {
        return (x.clone(), HostOp::default());
    }
    let mut out = TensorChw::zeros(x.c, x.h + 2 * p, x.w + 2 * p);
    pad_into(&x.data, (x.c, x.h, x.w), p, &mut out.data);
    (out, pad_cost(x.c, x.h, x.w, p))
}

/// Cost of [`pad_input`] without materializing it (the planner path).
pub fn pad_cost(c: usize, h: usize, w: usize, p: usize) -> HostOp {
    if p == 0 {
        return HostOp::default();
    }
    let padded = c * (h + 2 * p) * (w + 2 * p);
    HostOp {
        cycles: cycles_per_elem() * padded as u64,
        accesses: (c * h * w + padded) as u64,
    }
}

/// Allocation-free core of [`decimate`]: keep every `stride`-th pixel
/// per axis of the `(c, fh, fw)` source into the `(oc, oh, ow)`
/// destination. The compiled runner calls this against its arena.
pub fn decimate_into(
    src: &[i32],
    (c, fh, fw): (usize, usize, usize),
    stride: usize,
    dst: &mut [i32],
    (oc, oh, ow): (usize, usize, usize),
) {
    debug_assert_eq!(c, oc);
    for ci in 0..c {
        for y in 0..oh {
            for x in 0..ow {
                dst[(ci * oh + y) * ow + x] = src[(ci * fh + y * stride) * fw + x * stride];
            }
        }
    }
}

/// Keep every `stride`-th pixel per axis of a CHW tensor (`ox × oy`
/// outputs). The inverse charge of the stride lowering's overcompute.
pub fn decimate(full: &TensorChw, stride: usize, ox: usize, oy: usize) -> (TensorChw, HostOp) {
    if stride == 1 {
        // Nothing to do; the caller uses `full` as-is.
        return (full.clone(), HostOp::default());
    }
    let mut out = TensorChw::zeros(full.c, ox, oy);
    decimate_into(&full.data, (full.c, full.h, full.w), stride, &mut out.data, (full.c, ox, oy));
    let op = decimate_cost(full.c, stride, ox, oy);
    (out, op)
}

/// Cost of [`decimate`] (shared with the planner path).
pub fn decimate_cost(c: usize, stride: usize, ox: usize, oy: usize) -> HostOp {
    if stride == 1 {
        return HostOp::default();
    }
    let elems = (c * ox * oy) as u64;
    HostOp { cycles: cycles_per_elem() * elems, accesses: 2 * elems }
}

/// Copy channels `[lo, hi)` of a CHW tensor (contiguous in CHW).
pub fn slice_channels(x: &TensorChw, lo: usize, hi: usize) -> TensorChw {
    let per = x.h * x.w;
    TensorChw::from_vec(hi - lo, x.h, x.w, x.data[lo * per..hi * per].to_vec())
}

/// Concatenate per-group CHW outputs along the channel axis.
pub fn concat_channels(parts: Vec<TensorChw>) -> TensorChw {
    let (h, w) = (parts[0].h, parts[0].w);
    let c: usize = parts.iter().map(|p| p.c).sum();
    let mut data = Vec::with_capacity(c * h * w);
    for p in parts {
        assert_eq!((p.h, p.w), (h, w), "group outputs must share spatial dims");
        data.extend_from_slice(&p.data);
    }
    TensorChw::from_vec(c, h, w, data)
}

/// Cost of the group split + merge: each input element is sliced into
/// its group's buffer once, each output element concatenated once.
pub fn group_shuffle_cost(in_elems: usize, out_elems: usize) -> HostOp {
    let elems = (in_elems + out_elems) as u64;
    HostOp { cycles: cycles_per_elem() * elems, accesses: 2 * elems }
}

/// Per-window-element cycles of the pooling loops: one load plus one
/// compare/accumulate.
const POOL_CYCLES_PER_TAP: u64 = 5;
/// Per-output-element store cycles of the pooling loops.
const POOL_STORE_CYCLES: u64 = 4;

/// Allocation-free core of [`maxpool2d`] / [`avgpool2d`]: pool the
/// `(c, h, w)` source over `size × size` windows at `stride` into the
/// `(oc, oh, ow)` destination — max fold when `max`, else wrapping
/// accumulation with a truncating integer mean (like every other
/// integer op in the crate). The compiled runner calls this against
/// its arena.
pub fn pool_into(
    src: &[i32],
    (c, h, w): (usize, usize, usize),
    size: usize,
    stride: usize,
    max: bool,
    dst: &mut [i32],
    (oc, oh, ow): (usize, usize, usize),
) {
    debug_assert_eq!(c, oc);
    let n = (size * size) as i32;
    for ci in 0..c {
        for y in 0..oh {
            for x in 0..ow {
                let mut acc = if max { i32::MIN } else { 0 };
                for dy in 0..size {
                    for dx in 0..size {
                        let v = src[(ci * h + y * stride + dy) * w + x * stride + dx];
                        acc = if max { acc.max(v) } else { acc.wrapping_add(v) };
                    }
                }
                dst[(ci * oh + y) * ow + x] = if max { acc } else { acc / n };
            }
        }
    }
}

/// Max pooling over `size × size` windows at `stride` (host-side).
pub fn maxpool2d(x: &TensorChw, size: usize, stride: usize) -> (TensorChw, HostOp) {
    pool2d(x, size, stride, true)
}

/// Average pooling (truncating integer division by the window size,
/// wrapping accumulation like every other integer op in the crate).
pub fn avgpool2d(x: &TensorChw, size: usize, stride: usize) -> (TensorChw, HostOp) {
    pool2d(x, size, stride, false)
}

fn pool2d(x: &TensorChw, size: usize, stride: usize, max: bool) -> (TensorChw, HostOp) {
    assert!(size >= 1 && stride >= 1 && x.h >= size && x.w >= size);
    let (oh, ow) = ((x.h - size) / stride + 1, (x.w - size) / stride + 1);
    let mut out = TensorChw::zeros(x.c, oh, ow);
    pool_into(&x.data, (x.c, x.h, x.w), size, stride, max, &mut out.data, (x.c, oh, ow));
    (out, pool_cost(x.c, oh, ow, size))
}

/// Cost of one pooling pass (shared with the planner path).
pub fn pool_cost(c: usize, oh: usize, ow: usize, size: usize) -> HostOp {
    let outs = (c * oh * ow) as u64;
    let taps = outs * (size * size) as u64;
    HostOp {
        cycles: taps * POOL_CYCLES_PER_TAP + outs * POOL_STORE_CYCLES,
        accesses: taps + outs,
    }
}

/// How a conv-like layer reaches the engine: the stride-1 / valid 3×3
/// sub-convolution (per group), and the host glue around it.
#[derive(Clone, Debug)]
pub struct LoweredConv {
    /// The engine-visible per-group shape. For a stride-1 / pad-0 /
    /// groups-1 dense 3×3 layer this is exactly the layer's
    /// [`GenConvShape::to_basic`] shape — byte-identical cache and
    /// planner keys to the pre-generalization fast path.
    pub sub_shape: ConvShape,
    /// Independent group convolutions (1 for dense/depthwise).
    pub groups: usize,
    /// Strategy per sub-convolution ([`Mapping::DwWp`] for depthwise;
    /// the layer's mapping — often `Auto` — otherwise).
    pub mapping: Mapping,
    /// Zeros the host pads on each side before submission (layer pad,
    /// plus one extra ring for the pointwise embedding).
    pub host_pad: usize,
    /// The layer stride (host decimation factor after the engine runs).
    pub stride: usize,
    /// Logical output dims `(k, ox, oy)` after decimation/concat.
    pub out_dims: (usize, usize, usize),
    /// Whether the weights need the pointwise center-embedding pass.
    pub embed_pointwise: bool,
}

/// Lower a conv-like layer's shape. `depthwise` selects the Dw-WP
/// single-submission route.
pub fn lower_conv(shape: &GenConvShape, mapping: Mapping, depthwise: bool) -> Result<LoweredConv> {
    shape.validate()?;
    let pointwise = (shape.fx, shape.fy) == (1, 1);
    let host_pad = shape.pad + usize::from(pointwise);
    let (ihp, iwp) = (shape.ih + 2 * host_pad, shape.iw + 2 * host_pad);
    // Full stride-1 3×3 output of the padded input.
    let (oxf, oyf) = (ihp - 2, iwp - 2);
    let (sub_c, sub_k, groups) = if depthwise {
        ensure!(
            shape.k == shape.c && shape.groups == shape.c,
            "depthwise lowering needs groups == C == K, got {shape}"
        );
        (shape.c, shape.k, 1)
    } else {
        (shape.c_per_group(), shape.k_per_group(), shape.groups)
    };
    let sub_shape = ConvShape::checked(sub_c, sub_k, oxf, oyf)?;
    Ok(LoweredConv {
        sub_shape,
        groups,
        mapping: if depthwise { Mapping::DwWp } else { mapping },
        host_pad,
        stride: shape.stride,
        out_dims: (shape.k, shape.ox(), shape.oy()),
        embed_pointwise: pointwise,
    })
}

/// Center-embed a `(K, C, 1, 1)` filter bank into `(K, C, 3, 3)` (zero
/// taps everywhere else). One-time preparation, charged like the IP
/// kernel's padded weight image.
pub fn embed_pointwise_weights(w: &Weights) -> (Weights, HostOp) {
    assert_eq!((w.fy, w.fx), (1, 1), "embed_pointwise_weights takes a 1x1 bank");
    let mut out = Weights::zeros(w.k, w.c, 3, 3);
    for k in 0..w.k {
        for c in 0..w.c {
            out.set(k, c, 1, 1, w.at(k, c, 0, 0));
        }
    }
    let op = embed_pointwise_cost(w.k, w.c);
    (out, op)
}

/// Cost of [`embed_pointwise_weights`] (shared with the planner path).
pub fn embed_pointwise_cost(k: usize, c: usize) -> HostOp {
    let elems = (k * c * 9) as u64;
    HostOp {
        cycles: HostCostModel::default().prep_cycles_per_elem * elems,
        accesses: (k * c) as u64 + elems,
    }
}

/// Everything the execution stack needs to know about one layer's
/// lowering, resolved once: the engine-visible sub-convolution (for
/// conv-like layers), the layer's **static host-glue charge** (pad +
/// pointwise embed + group shuffle + decimate + pool — every term is
/// closed-form in the dims, so it is identical for the planner, the
/// compiler and the executor *by construction*), and the output dims.
///
/// This is the single lowering path of the crate: `nn::plan` prices
/// layers from it, `engine::compiled` freezes step lists from it, and
/// `nn::exec` executes through those compiled steps — the three
/// formerly-duplicated per-layer glue sequences collapsed into one.
#[derive(Clone, Debug)]
pub struct GlueSpec {
    /// The lowered sub-convolution (`None` for host-only pooling).
    pub lowered: Option<LoweredConv>,
    /// Static host glue of the layer (excludes the fused ReLU, which is
    /// charged separately like the engine does).
    pub host: HostOp,
    /// Input dims `(c, h, w)` the layer consumes.
    pub in_dims: (usize, usize, usize),
    /// Input dims after the host pad (equals `in_dims` when no pad).
    pub padded_dims: (usize, usize, usize),
    /// Output dims `(c, h, w)` the layer produces.
    pub out_dims: (usize, usize, usize),
}

/// Resolve a layer's lowering and its static glue charge for an input
/// of `in_dims`. Validates that the layer accepts those dims.
pub fn glue_spec(layer: &Layer, in_dims: (usize, usize, usize)) -> Result<GlueSpec> {
    let (c, h, w) = in_dims;
    let out_dims = layer.out_dims(in_dims)?;
    let mut host = HostOp::default();
    match layer {
        Layer::MaxPool { size, .. } | Layer::AvgPool { size, .. } => {
            let (oc, oh, ow) = out_dims;
            debug_assert_eq!(oc, c);
            host.add(pool_cost(c, oh, ow, *size));
            Ok(GlueSpec { lowered: None, host, in_dims, padded_dims: in_dims, out_dims })
        }
        conv_like => {
            let shape = conv_like.conv_shape().expect("conv-like layer has a shape");
            let depthwise = matches!(conv_like, Layer::Depthwise { .. });
            let mapping = match conv_like {
                Layer::Conv { mapping, .. } | Layer::Pointwise { mapping, .. } => *mapping,
                _ => Mapping::Auto,
            };
            let lc = lower_conv(shape, mapping, depthwise)?;
            host.add(pad_cost(c, h, w, lc.host_pad));
            if lc.embed_pointwise {
                host.add(embed_pointwise_cost(shape.k, shape.c_per_group()));
            }
            let padded_dims = (c, h + 2 * lc.host_pad, w + 2 * lc.host_pad);
            if lc.groups > 1 {
                let padded = c * padded_dims.1 * padded_dims.2;
                host.add(group_shuffle_cost(padded, lc.groups * lc.sub_shape.output_elems()));
            }
            if lc.stride > 1 {
                let (k, ox, oy) = lc.out_dims;
                host.add(decimate_cost(k, lc.stride, ox, oy));
            }
            Ok(GlueSpec { lowered: Some(lc), host, in_dims, padded_dims, out_dims })
        }
    }
}

/// Scalar-CPU baseline cycles of the *logical* layer (true MACs, true
/// output size) — the per-layer speedup denominator of the network
/// report. Pools return 0 (they run on the host either way).
pub fn cpu_baseline_cycles(layer: &Layer) -> u64 {
    match layer.conv_shape() {
        None => 0,
        Some(s) => {
            let m = CpuModel::default();
            (s.macs() as f64 * m.cycles_per_mac()
                + s.output_elems() as f64 * m.store_latency)
                .round() as u64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::Rng;

    #[test]
    fn pad_embeds_and_charges() {
        let mut rng = Rng::new(1);
        let x = TensorChw::random(2, 3, 4, 10, &mut rng);
        let (p, op) = pad_input(&x, 1);
        assert_eq!((p.c, p.h, p.w), (2, 5, 6));
        assert_eq!(p.at(0, 0, 0), 0);
        assert_eq!(p.at(1, 1, 1), x.at(1, 0, 0));
        assert_eq!(p.at(1, 3, 4), x.at(1, 2, 3));
        assert_eq!(op.cycles, 3 * 2 * 5 * 6);
        assert_eq!(op, pad_cost(2, 3, 4, 1));
        // p = 0 is free.
        assert_eq!(pad_input(&x, 0).1, HostOp::default());
    }

    #[test]
    fn decimate_samples_every_stride() {
        let x = TensorChw::from_vec(1, 4, 4, (0..16).collect());
        let (d, op) = decimate(&x, 2, 2, 2);
        assert_eq!(d.data, vec![0, 2, 8, 10]);
        assert_eq!(op, decimate_cost(1, 2, 2, 2));
        assert!(op.cycles > 0);
    }

    #[test]
    fn slice_concat_round_trip() {
        let mut rng = Rng::new(2);
        let x = TensorChw::random(6, 3, 3, 9, &mut rng);
        let parts: Vec<TensorChw> =
            (0..3).map(|g| slice_channels(&x, g * 2, (g + 1) * 2)).collect();
        assert_eq!(concat_channels(parts), x);
    }

    #[test]
    fn pooling_math_and_identities() {
        let x = TensorChw::from_vec(1, 4, 4, (1..=16).collect());
        let (mx, _) = maxpool2d(&x, 2, 2);
        assert_eq!(mx.data, vec![6, 8, 14, 16]);
        let (avg, _) = avgpool2d(&x, 2, 2);
        // Truncated window means: 14/4, 22/4, 46/4, 54/4.
        assert_eq!(avg.data, vec![3, 5, 11, 13]);
        // size-1 stride-1 pooling is the identity.
        assert_eq!(maxpool2d(&x, 1, 1).0, x);
        assert_eq!(avgpool2d(&x, 1, 1).0, x);
        // Max of a window is >= its truncated mean.
        for (a, b) in mx.data.iter().zip(avg.data.iter()) {
            assert!(a >= b);
        }
    }

    #[test]
    fn lower_conv_fast_path_is_the_basic_shape() {
        let g = GenConvShape::new(3, 5, 10, 12, 3, 3, 1, 0, 1).unwrap();
        let l = lower_conv(&g, Mapping::Auto, false).unwrap();
        assert_eq!(Some(l.sub_shape), g.to_basic());
        assert_eq!(l.groups, 1);
        assert_eq!(l.host_pad, 0);
        assert_eq!(l.stride, 1);
        assert!(!l.embed_pointwise);
    }

    #[test]
    fn lower_conv_strided_padded_grouped() {
        let g = GenConvShape::new(4, 8, 16, 16, 3, 3, 2, 1, 2).unwrap();
        let l = lower_conv(&g, Mapping::Auto, false).unwrap();
        // Padded to 18x18, full stride-1 output 16x16, per group 2->4.
        assert_eq!(l.sub_shape, ConvShape::new3x3(2, 4, 16, 16));
        assert_eq!(l.groups, 2);
        assert_eq!(l.host_pad, 1);
        assert_eq!(l.stride, 2);
        assert_eq!(l.out_dims, (8, 8, 8));
    }

    #[test]
    fn lower_pointwise_adds_the_embedding_ring() {
        let g = GenConvShape::new(8, 16, 7, 7, 1, 1, 1, 0, 1).unwrap();
        let l = lower_conv(&g, Mapping::Auto, false).unwrap();
        assert!(l.embed_pointwise);
        assert_eq!(l.host_pad, 1);
        // 9x9 padded input, 3x3 valid -> 7x7: the pointwise output size.
        assert_eq!(l.sub_shape, ConvShape::new3x3(8, 16, 7, 7));
        assert_eq!(l.out_dims, (16, 7, 7));
    }

    #[test]
    fn lower_depthwise_routes_to_dw_wp() {
        let g = GenConvShape::new(8, 8, 10, 10, 3, 3, 1, 1, 8).unwrap();
        let l = lower_conv(&g, Mapping::Auto, true).unwrap();
        assert_eq!(l.mapping, Mapping::DwWp);
        assert_eq!(l.groups, 1, "depthwise is one submission, C launches inside");
        assert_eq!(l.sub_shape, ConvShape::new3x3(8, 8, 10, 10));
    }

    #[test]
    fn pointwise_embedding_is_exact() {
        let mut rng = Rng::new(3);
        let w = Weights::random(3, 2, 1, 1, 9, &mut rng);
        let (e, op) = embed_pointwise_weights(&w);
        assert_eq!(e.at(2, 1, 1, 1), w.at(2, 1, 0, 0));
        assert_eq!(e.at(2, 1, 0, 0), 0);
        assert_eq!(op, embed_pointwise_cost(3, 2));
        // A 1x1 conv over x equals the embedded 3x3 over zero-ring-padded x.
        let g1 = GenConvShape::new(2, 3, 4, 4, 1, 1, 1, 0, 1).unwrap();
        let x = TensorChw::random(2, 4, 4, 20, &mut rng);
        let direct = crate::conv::conv2d_general(&g1, &x, &w);
        let (xp, _) = pad_input(&x, 1);
        let g3 = GenConvShape::new(2, 3, 6, 6, 3, 3, 1, 0, 1).unwrap();
        let via3x3 = crate::conv::conv2d_general(&g3, &xp, &e);
        assert_eq!(direct.data, via3x3.data);
    }

    #[test]
    fn host_energy_is_positive_and_linear_in_cycles() {
        let m = EnergyModel::default();
        let a = host_energy_uj(&m, HostOp { cycles: 100, accesses: 10 });
        let b = host_energy_uj(&m, HostOp { cycles: 200, accesses: 20 });
        assert!(a > 0.0);
        assert!((b - 2.0 * a).abs() < 1e-12);
    }
}
