//! The typed layer-graph IR: a feed-forward stack of generalized
//! layers — dense/grouped convolutions with stride and padding,
//! depthwise and pointwise convolutions, max/average pooling — each
//! with an optional fused host-side ReLU, plus the golden CPU reference
//! the executor is checked against layer by layer.

use anyhow::{bail, ensure, Context, Result};

use crate::conv::{conv2d_general, GenConvShape, TensorChw, Weights};
use crate::kernels::Mapping;
use crate::prop::Rng;

use super::lower::{avgpool2d, maxpool2d};

/// One layer of the graph. Convolution variants carry their weights
/// inline; the mapping field may be [`Mapping::Auto`] (the planner
/// picks per layer at lowering time) or any concrete dense mapping.
#[derive(Clone, Debug)]
pub enum Layer {
    /// Generalized convolution: stride / zero padding / channel groups,
    /// 3×3 filter. Weights `(K, C/groups, 3, 3)`.
    Conv {
        /// Layer hyper-parameters.
        shape: GenConvShape,
        /// Filter bank.
        weights: Weights,
        /// Strategy for the lowered stride-1 convolutions.
        mapping: Mapping,
        /// Fused host-side ReLU after the convolution.
        relu: bool,
    },
    /// Depthwise convolution (`groups == C == K`): one 3×3 filter per
    /// channel, weights `(C, 1, 3, 3)`. Runs on the CGRA via the
    /// `Dw-WP` kernel.
    Depthwise {
        /// Layer hyper-parameters (`is_depthwise()` holds).
        shape: GenConvShape,
        /// One single-channel filter per channel.
        weights: Weights,
        /// Fused host-side ReLU.
        relu: bool,
    },
    /// Pointwise (1×1) convolution. Weights `(K, C, 1, 1)`. Lowered to
    /// a center-embedded 3×3 over a one-zero-ring-padded input.
    Pointwise {
        /// Layer hyper-parameters (`fx == fy == 1`).
        shape: GenConvShape,
        /// The 1×1 filter bank.
        weights: Weights,
        /// Strategy for the lowered stride-1 convolutions.
        mapping: Mapping,
        /// Fused host-side ReLU.
        relu: bool,
    },
    /// Host-side max pooling over `size × size` windows.
    MaxPool {
        /// Window side.
        size: usize,
        /// Window stride.
        stride: usize,
    },
    /// Host-side average pooling (truncating integer mean).
    AvgPool {
        /// Window side.
        size: usize,
        /// Window stride.
        stride: usize,
    },
}

impl Layer {
    /// A dense or grouped 3×3 convolution with deterministic random
    /// weights.
    pub fn conv(shape: GenConvShape, relu: bool, mag: i32, rng: &mut Rng) -> Result<Layer> {
        shape.validate()?;
        ensure!((shape.fx, shape.fy) == (3, 3), "Layer::conv is the 3x3 variant");
        let weights = Weights::random(shape.k, shape.c_per_group(), 3, 3, mag, rng);
        Ok(Layer::Conv { shape, weights, mapping: Mapping::Auto, relu })
    }

    /// A depthwise 3×3 convolution (`k == c`, one filter per channel)
    /// with deterministic random weights.
    #[allow(clippy::too_many_arguments)]
    pub fn depthwise(
        c: usize,
        ih: usize,
        iw: usize,
        stride: usize,
        pad: usize,
        relu: bool,
        mag: i32,
        rng: &mut Rng,
    ) -> Result<Layer> {
        let shape = GenConvShape::new(c, c, ih, iw, 3, 3, stride, pad, c)?;
        ensure!(shape.is_depthwise() || c == 1, "depthwise needs at least one channel");
        let weights = Weights::random(c, 1, 3, 3, mag, rng);
        Ok(Layer::Depthwise { shape, weights, relu })
    }

    /// A pointwise (1×1, stride 1, no padding) convolution with
    /// deterministic random weights.
    pub fn pointwise(
        c: usize,
        k: usize,
        ih: usize,
        iw: usize,
        relu: bool,
        mag: i32,
        rng: &mut Rng,
    ) -> Result<Layer> {
        let shape = GenConvShape::new(c, k, ih, iw, 1, 1, 1, 0, 1)?;
        let weights = Weights::random(k, c, 1, 1, mag, rng);
        Ok(Layer::Pointwise { shape, weights, mapping: Mapping::Auto, relu })
    }

    /// Max pooling.
    pub fn maxpool(size: usize, stride: usize) -> Layer {
        Layer::MaxPool { size, stride }
    }

    /// Average pooling.
    pub fn avgpool(size: usize, stride: usize) -> Layer {
        Layer::AvgPool { size, stride }
    }

    /// Short kind label for reports.
    pub fn kind(&self) -> &'static str {
        match self {
            Layer::Conv { .. } => "conv",
            Layer::Depthwise { .. } => "depthwise",
            Layer::Pointwise { .. } => "pointwise",
            Layer::MaxPool { .. } => "maxpool",
            Layer::AvgPool { .. } => "avgpool",
        }
    }

    /// One-line description for reports.
    pub fn describe(&self) -> String {
        match self {
            Layer::Conv { shape, .. }
            | Layer::Depthwise { shape, .. }
            | Layer::Pointwise { shape, .. } => shape.id(),
            Layer::MaxPool { size, stride } | Layer::AvgPool { size, stride } => {
                format!("{size}x{size}/s{stride}")
            }
        }
    }

    /// The convolution shape, for conv-like layers.
    pub fn conv_shape(&self) -> Option<&GenConvShape> {
        match self {
            Layer::Conv { shape, .. }
            | Layer::Depthwise { shape, .. }
            | Layer::Pointwise { shape, .. } => Some(shape),
            _ => None,
        }
    }

    /// Whether a fused ReLU follows the layer.
    pub fn relu(&self) -> bool {
        match self {
            Layer::Conv { relu, .. }
            | Layer::Depthwise { relu, .. }
            | Layer::Pointwise { relu, .. } => *relu,
            _ => false,
        }
    }

    /// True multiply-accumulates of the layer (0 for pooling).
    pub fn macs(&self) -> u64 {
        self.conv_shape().map(|s| s.macs()).unwrap_or(0)
    }

    /// Output dims `(c, h, w)` for an input of `dims`, validating that
    /// the layer accepts it.
    pub fn out_dims(&self, dims: (usize, usize, usize)) -> Result<(usize, usize, usize)> {
        let (c, h, w) = dims;
        match self {
            Layer::Conv { shape, .. }
            | Layer::Depthwise { shape, .. }
            | Layer::Pointwise { shape, .. } => {
                ensure!(
                    (shape.c, shape.ih, shape.iw) == (c, h, w),
                    "{} layer expects input {}x{}x{}, got {c}x{h}x{w}",
                    self.kind(),
                    shape.c,
                    shape.ih,
                    shape.iw
                );
                Ok((shape.k, shape.ox(), shape.oy()))
            }
            Layer::MaxPool { size, stride } | Layer::AvgPool { size, stride } => {
                ensure!(*size >= 1 && *stride >= 1, "pool size/stride must be at least 1");
                ensure!(
                    h >= *size && w >= *size,
                    "{}x{} input smaller than the {size}x{size} pool window",
                    h,
                    w
                );
                Ok((c, (h - size) / stride + 1, (w - size) / stride + 1))
            }
        }
    }
}

/// A feed-forward layer graph with a fixed input signature.
#[derive(Clone, Debug)]
pub struct Net {
    /// Network name (preset name, or a descriptive label).
    pub name: String,
    /// Input dims `(c, h, w)`.
    pub input_dims: (usize, usize, usize),
    /// Layers in execution order.
    pub layers: Vec<Layer>,
}

impl Net {
    /// Validate the whole graph: every layer accepts its predecessor's
    /// output.
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.layers.is_empty(), "network '{}' has no layers", self.name);
        let mut dims = self.input_dims;
        for (i, layer) in self.layers.iter().enumerate() {
            dims = layer
                .out_dims(dims)
                .with_context(|| format!("layer {i} ({}) of '{}'", layer.kind(), self.name))?;
        }
        Ok(())
    }

    /// Output dims of the whole network.
    pub fn output_dims(&self) -> Result<(usize, usize, usize)> {
        let mut dims = self.input_dims;
        for layer in &self.layers {
            dims = layer.out_dims(dims)?;
        }
        Ok(dims)
    }

    /// Total true MACs.
    pub fn macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// A plain stride-1 / valid stack of `depth` dense 3×3 conv+ReLU
    /// layers — the generalized equivalent of the pre-nn
    /// `ConvNet::random` CNN (`cgra net` without a preset).
    pub fn plain_stack(depth: usize, c0: usize, k: usize, hw: usize, seed: u64) -> Result<Net> {
        ensure!(depth >= 1, "need at least one layer");
        let mut rng = Rng::new(seed);
        let mut layers = Vec::new();
        let (mut c, mut h, mut w) = (c0, hw, hw);
        for d in 0..depth {
            let shape = GenConvShape::new(c, k, h, w, 3, 3, 1, 0, 1)?;
            let relu = d + 1 < depth;
            layers.push(Layer::conv(shape, relu, 4, &mut rng)?);
            c = k;
            h = shape.ox();
            w = shape.oy();
        }
        Ok(Net { name: format!("stack-{depth}x{k}"), input_dims: (c0, hw, hw), layers })
    }

    /// Deterministic random input tensor for this network.
    pub fn random_input(&self, mag: i32, seed: u64) -> TensorChw {
        let (c, h, w) = self.input_dims;
        TensorChw::random(c, h, w, mag, &mut Rng::new(seed))
    }

    /// Structural fingerprint of the network: input signature, per-layer
    /// kind and hyper-parameters, requested mappings, fused-ReLU flags
    /// and the full weight data, FNV-folded into one `u64`. Two nets
    /// with equal fingerprints compile to interchangeable artifacts
    /// (same programs, same baked weights); the cosmetic `name` is
    /// deliberately excluded. The serving daemon keys its artifact
    /// registry on this, combined with the session fingerprint
    /// ([`crate::engine::Engine::session_fingerprint`]).
    pub fn fingerprint(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let mut mix = |v: u64| h = (h ^ v).wrapping_mul(0x1000_0000_01b3);
        let mix_shape = |mix: &mut dyn FnMut(u64), s: &GenConvShape| {
            for v in [s.c, s.k, s.ih, s.iw, s.fx, s.fy, s.stride, s.pad, s.groups] {
                mix(v as u64);
            }
        };
        let mix_weights = |mix: &mut dyn FnMut(u64), w: &Weights| {
            for v in [w.k, w.c, w.fy, w.fx] {
                mix(v as u64);
            }
            for &x in &w.data {
                mix(x as u32 as u64);
            }
        };
        let (c, ih, iw) = self.input_dims;
        for v in [c, ih, iw, self.layers.len()] {
            mix(v as u64);
        }
        for layer in &self.layers {
            match layer {
                Layer::Conv { shape, weights, mapping, relu } => {
                    mix(1);
                    mix_shape(&mut mix, shape);
                    mix_weights(&mut mix, weights);
                    for b in mapping.label().bytes() {
                        mix(b as u64);
                    }
                    mix(*relu as u64);
                }
                Layer::Depthwise { shape, weights, relu } => {
                    mix(2);
                    mix_shape(&mut mix, shape);
                    mix_weights(&mut mix, weights);
                    mix(*relu as u64);
                }
                Layer::Pointwise { shape, weights, mapping, relu } => {
                    mix(3);
                    mix_shape(&mut mix, shape);
                    mix_weights(&mut mix, weights);
                    for b in mapping.label().bytes() {
                        mix(b as u64);
                    }
                    mix(*relu as u64);
                }
                Layer::MaxPool { size, stride } => {
                    mix(4);
                    mix(*size as u64);
                    mix(*stride as u64);
                }
                Layer::AvgPool { size, stride } => {
                    mix(5);
                    mix(*size as u64);
                    mix(*stride as u64);
                }
            }
        }
        h
    }
}

/// Apply a fused ReLU in place (shared by the golden chain and the
/// executor so both clamp identically).
pub(crate) fn relu_in_place(t: &mut TensorChw) {
    for v in t.data.iter_mut() {
        *v = (*v).max(0);
    }
}

/// Golden CPU reference of one layer (wrapping int32 + ReLU): the
/// generalized direct convolution for every conv variant (depthwise is
/// its `groups == C` case), the host pooling ops for pools.
pub fn golden_layer(layer: &Layer, input: &TensorChw) -> Result<TensorChw> {
    let mut out = match layer {
        Layer::Conv { shape, weights, .. }
        | Layer::Depthwise { shape, weights, .. }
        | Layer::Pointwise { shape, weights, .. } => conv2d_general(shape, input, weights),
        Layer::MaxPool { size, stride } => maxpool2d(input, *size, *stride).0,
        Layer::AvgPool { size, stride } => avgpool2d(input, *size, *stride).0,
    };
    if layer.relu() {
        relu_in_place(&mut out);
    }
    Ok(out)
}

/// Golden CPU reference of the whole network: per-layer outputs in
/// execution order (the executor checks its layer outputs against
/// these, element-exactly).
pub fn golden_network(net: &Net, input: &TensorChw) -> Result<Vec<TensorChw>> {
    net.validate()?;
    let (c, h, w) = net.input_dims;
    if input.c != c || input.h != h || input.w != w {
        bail!(
            "network '{}' expects a {c}x{h}x{w} input, got {}x{}x{}",
            net.name,
            input.c,
            input.h,
            input.w
        );
    }
    let mut outs = Vec::with_capacity(net.layers.len());
    let mut x = input.clone();
    for layer in &net.layers {
        x = golden_layer(layer, &x)?;
        outs.push(x.clone());
    }
    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_net() -> Net {
        let mut rng = Rng::new(1);
        let conv = Layer::conv(
            GenConvShape::new(2, 4, 8, 8, 3, 3, 2, 1, 1).unwrap(),
            true,
            4,
            &mut rng,
        )
        .unwrap(); // -> 4x4x4
        let dw = Layer::depthwise(4, 4, 4, 1, 1, true, 4, &mut rng).unwrap(); // -> 4x4x4
        let pw = Layer::pointwise(4, 6, 4, 4, false, 4, &mut rng).unwrap(); // -> 6x4x4
        let pool = Layer::maxpool(2, 2); // -> 6x2x2
        Net {
            name: "tiny".into(),
            input_dims: (2, 8, 8),
            layers: vec![conv, dw, pw, pool],
        }
    }

    #[test]
    fn dims_chain_through_all_layer_kinds() {
        let net = tiny_net();
        net.validate().unwrap();
        assert_eq!(net.output_dims().unwrap(), (6, 2, 2));
        assert_eq!(net.layers[0].kind(), "conv");
        assert_eq!(net.layers[1].kind(), "depthwise");
        assert_eq!(net.layers[2].kind(), "pointwise");
        assert_eq!(net.layers[3].kind(), "maxpool");
        // MACs: conv 2*4*4*4*9 + dw 4*4*4*9 + pw 4*6*4*4; pool adds 0.
        assert_eq!(net.macs(), 2 * 4 * 16 * 9 + 4 * 16 * 9 + 4 * 6 * 16);
    }

    #[test]
    fn mismatched_chains_are_rejected_with_layer_index() {
        let mut net = tiny_net();
        // Drop the first conv: the depthwise layer now sees the 2x8x8
        // network input instead of its expected 4x4x4.
        net.layers.remove(0);
        let err = format!("{:#}", net.validate().unwrap_err());
        assert!(err.contains("layer 0") && err.contains("depthwise"), "{err}");
    }

    #[test]
    fn golden_network_chains_and_applies_relu() {
        let net = tiny_net();
        let input = net.random_input(10, 5);
        let outs = golden_network(&net, &input).unwrap();
        assert_eq!(outs.len(), 4);
        // ReLU layers have no negative outputs.
        assert!(outs[0].data.iter().all(|&v| v >= 0));
        assert!(outs[1].data.iter().all(|&v| v >= 0));
        // Final dims match.
        assert_eq!((outs[3].c, outs[3].h, outs[3].w), (6, 2, 2));
        // Wrong input dims are rejected.
        let bad = TensorChw::zeros(1, 8, 8);
        assert!(golden_network(&net, &bad).is_err());
    }

    #[test]
    fn plain_stack_matches_legacy_random_net_shapes() {
        let net = Net::plain_stack(3, 3, 8, 12, 7).unwrap();
        net.validate().unwrap();
        assert_eq!(net.output_dims().unwrap(), (8, 6, 6));
        assert!(net.layers[0].relu() && !net.layers[2].relu());
        // Every layer is a stride-1 basic shape (the fast path).
        for l in &net.layers {
            assert!(l.conv_shape().unwrap().to_basic().is_some());
        }
    }
}
