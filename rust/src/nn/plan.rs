//! Plan a whole [`Net`] through the analytical cost model — per-layer
//! mapping choice and predicted cycles/energy — **without simulating a
//! single convolution**.
//!
//! The conv part of each layer is priced by the [`Planner`] on the same
//! lowered stride-1 shapes the executor submits; the host glue (pad /
//! group shuffle / decimate / pool / fused ReLU) uses the identical
//! closed forms from `nn::lower`. Under the **latency** objective a
//! plan resolves `Mapping::Auto` exactly like the executor (the
//! engine's cost-backed policy is latency-only), so plan totals are
//! directly comparable to `nn::exec::run_network`'s within the
//! planner's ≤ 5 % validated bound — `cgra plan --validate` checks one
//! strided layer end to end this way. Under the **energy** objective
//! the plan may choose mappings the executor's `Auto` would not; pin
//! the planned mappings into the layers to execute such a plan.

use anyhow::{Context, Result};

use crate::engine::relu_cost;
use crate::kernels::Mapping;
use crate::planner::{PlanObjective, Planner};

use super::graph::Net;
use super::lower::{cpu_baseline_cycles, glue_spec, host_energy_uj};

/// The predicted cost and chosen strategy of one layer.
#[derive(Clone, Debug)]
pub struct LayerPlanReport {
    /// Layer index in execution order.
    pub index: usize,
    /// Layer kind label.
    pub kind: &'static str,
    /// Short shape description.
    pub desc: String,
    /// The strategy the plan costs (`None` for host-only pooling).
    pub mapping: Option<Mapping>,
    /// Predicted end-to-end layer cycles (conv + glue + ReLU).
    pub cycles: u64,
    /// Predicted CGRA convolution cycles.
    pub conv_cycles: u64,
    /// Predicted host glue cycles (incl. the fused ReLU).
    pub host_cycles: u64,
    /// Predicted layer energy, µJ.
    pub energy_uj: f64,
    /// True MACs of the layer.
    pub macs: u64,
    /// Scalar-CPU baseline cycles (0 for pools).
    pub cpu_cycles: u64,
}

/// A whole-network plan.
#[derive(Clone, Debug)]
pub struct NetPlan {
    /// Network name.
    pub name: String,
    /// The objective the per-layer choice minimized.
    pub objective: PlanObjective,
    /// Per-layer predictions, in execution order.
    pub layers: Vec<LayerPlanReport>,
    /// Predicted end-to-end cycles.
    pub total_cycles: u64,
    /// Predicted end-to-end energy, µJ.
    pub total_energy_uj: f64,
}

/// Plan every layer of `net` under `objective`. Layers with
/// [`Mapping::Auto`] pick the cheapest in-bound CGRA mapping by
/// predicted cost; depthwise layers cost the `Dw-WP` kernel; explicit
/// mappings are priced as requested.
pub fn plan_network(planner: &Planner, net: &Net, objective: PlanObjective) -> Result<NetPlan> {
    net.validate()?;
    let model = *planner.energy_model();
    let mut dims = net.input_dims;
    let mut layers = Vec::with_capacity(net.layers.len());
    let mut total_cycles = 0u64;
    let mut total_energy = 0.0f64;
    for (index, layer) in net.layers.iter().enumerate() {
        let ctx = || format!("planning layer {index} ({}) of '{}'", layer.kind(), net.name);
        // The one lowering path of the crate: the same `glue_spec` the
        // compiler freezes step lists from (engine::compiled) prices
        // this plan's host glue, so predicted and executed glue are
        // identical by construction.
        let spec = glue_spec(layer, dims).with_context(ctx)?;
        let out_dims = spec.out_dims;
        let host = spec.host;
        let mut conv_cycles = 0u64;
        let mut conv_energy = 0.0f64;
        let mut mapping: Option<Mapping> = None;

        if let Some(lc) = &spec.lowered {
            // The per-group estimate: every group shares one
            // (shape, mapping) point, so the planner memo makes the
            // repeats free; multiplying is exact because the executor
            // submits `groups` independent convolutions.
            let est = match lc.mapping {
                Mapping::Auto => planner
                    .best_of(&lc.sub_shape, &Mapping::CGRA, objective)
                    .with_context(ctx)?,
                m => planner.estimate(&lc.sub_shape, m).with_context(ctx)?,
            };
            mapping = Some(est.mapping);
            conv_cycles = est.cycles() * lc.groups as u64;
            conv_energy = est.energy_uj() * lc.groups as f64;
        }
        let (relu_cycles, relu_uj) = if layer.relu() {
            let (oc, oh, ow) = out_dims;
            relu_cost(&model, oc * oh * ow)
        } else {
            (0, 0.0)
        };

        let cycles = conv_cycles + host.cycles + relu_cycles;
        let energy_uj = conv_energy + host_energy_uj(&model, host) + relu_uj;
        total_cycles += cycles;
        total_energy += energy_uj;
        layers.push(LayerPlanReport {
            index,
            kind: layer.kind(),
            desc: layer.describe(),
            mapping,
            cycles,
            conv_cycles,
            host_cycles: host.cycles + relu_cycles,
            energy_uj,
            macs: layer.macs(),
            cpu_cycles: cpu_baseline_cycles(layer),
        });
        dims = out_dims;
    }
    Ok(NetPlan {
        name: net.name.clone(),
        objective,
        layers,
        total_cycles,
        total_energy_uj: total_energy,
    })
}

#[cfg(test)]
mod tests {
    use super::super::graph::Layer;
    use super::*;
    use crate::cgra::CgraConfig;
    use crate::energy::EnergyModel;
    use crate::engine::EngineBuilder;
    use crate::prop::Rng;

    fn planner() -> Planner {
        Planner::new(&CgraConfig::default(), &EnergyModel::default()).unwrap()
    }

    fn mixed_net() -> Net {
        let mut rng = Rng::new(9);
        Net {
            name: "mixed".into(),
            input_dims: (2, 10, 10),
            layers: vec![
                Layer::conv(
                    crate::conv::GenConvShape::new(2, 4, 10, 10, 3, 3, 2, 1, 1).unwrap(),
                    true,
                    4,
                    &mut rng,
                )
                .unwrap(),
                Layer::depthwise(4, 5, 5, 1, 1, true, 4, &mut rng).unwrap(),
                Layer::pointwise(4, 8, 5, 5, true, 4, &mut rng).unwrap(),
                Layer::maxpool(2, 2),
            ],
        }
    }

    /// The plan prices every layer, never simulates a full layer, and
    /// tracks the executed network within the planner's bound.
    #[test]
    fn plan_tracks_execution_within_the_bound() {
        let p = planner();
        let net = mixed_net();
        let plan = plan_network(&p, &net, PlanObjective::Latency).unwrap();
        assert_eq!(plan.layers.len(), 4);
        assert_eq!(plan.layers[1].mapping, Some(Mapping::DwWp));
        assert_eq!(plan.layers[3].mapping, None);
        assert_eq!(
            plan.total_cycles,
            plan.layers.iter().map(|l| l.cycles).sum::<u64>()
        );
        // Compare against the real execution.
        let engine = EngineBuilder::new().workers(2).private_cache().build().unwrap();
        let input = net.random_input(10, 3);
        let report = super::super::exec::run_network(&engine, &net, &input).unwrap();
        let (pc, sc) = (plan.total_cycles as f64, report.total_cycles as f64);
        assert!(
            ((pc - sc) / sc).abs() <= 0.05,
            "planned {pc} vs executed {sc} cycles"
        );
        // Host glue is closed-form-identical, layer by layer.
        for (a, b) in plan.layers.iter().zip(report.layers.iter()) {
            assert_eq!(a.host_cycles, b.host_cycles, "layer {} glue", a.index);
            assert_eq!(a.mapping, b.mapping, "layer {} mapping", a.index);
            assert_eq!(a.cpu_cycles, b.cpu_cycles, "layer {} baseline", a.index);
        }
    }

    /// Objectives steer the per-layer choice deterministically.
    #[test]
    fn objective_is_threaded_through() {
        let p = planner();
        let net = Net::plain_stack(2, 2, 4, 8, 5).unwrap();
        let lat = plan_network(&p, &net, PlanObjective::Latency).unwrap();
        let eng = plan_network(&p, &net, PlanObjective::Energy).unwrap();
        assert_eq!(lat.objective, PlanObjective::Latency);
        assert_eq!(eng.objective, PlanObjective::Energy);
        // On the paper's shapes WP wins both objectives.
        assert_eq!(lat.layers[0].mapping, Some(Mapping::Wp));
        assert_eq!(eng.layers[0].mapping, Some(Mapping::Wp));
    }

    /// Over-bound layers fail with the layer context, like the executor.
    #[test]
    fn plan_errors_carry_layer_context() {
        let p = planner();
        let mut rng = Rng::new(1);
        let net = Net {
            name: "big".into(),
            input_dims: (16, 66, 66),
            layers: vec![Layer::conv(
                crate::conv::GenConvShape::new(16, 16, 66, 66, 3, 3, 1, 0, 1).unwrap(),
                false,
                2,
                &mut rng,
            )
            .unwrap()],
        };
        let err = format!("{:#}", plan_network(&p, &net, PlanObjective::Latency).unwrap_err());
        assert!(err.contains("planning layer 0"), "{err}");
    }
}
