//! Named network presets: the edge-CNN layer mixes the paper targets,
//! buildable by name from the CLI (`cgra net --preset <name>`).
//! Weights are deterministic in the seed, so every run (and CI) sees
//! identical networks.

use anyhow::{bail, Result};

use crate::conv::GenConvShape;
use crate::prop::Rng;

use super::graph::{Layer, Net};

/// A named preset.
#[derive(Clone, Copy, Debug)]
pub struct Preset {
    /// CLI name.
    pub name: &'static str,
    /// One-line description (shown in `cgra net` help/errors).
    pub about: &'static str,
}

/// Every available preset, in display order.
pub const PRESETS: [Preset; 3] = [
    Preset {
        name: "mobilenet-mini",
        about: "depthwise-separable stack (strided conv, dw/pw pairs, avgpool) on 3x32x32",
    },
    Preset {
        name: "paper-baseline",
        about: "the paper's baseline layer (C=K=Ox=Oy=16, 3x3, stride 1) as a one-layer net",
    },
    Preset {
        name: "vgg-mini",
        about: "VGG-ish stack: padded 3x3 convs, maxpools, one strided conv, on 3x16x16",
    },
];

/// The comma-separated preset list (help text and error messages).
pub fn preset_names() -> String {
    PRESETS.iter().map(|p| p.name).collect::<Vec<_>>().join(" | ")
}

/// Build a preset by name with weights deterministic in `seed`. The
/// error for an unknown name lists every preset with its description.
pub fn build(name: &str, seed: u64) -> Result<Net> {
    let mut rng = Rng::new(seed);
    match name {
        "mobilenet-mini" => mobilenet_mini(&mut rng),
        "paper-baseline" => paper_baseline(&mut rng),
        "vgg-mini" => vgg_mini(&mut rng),
        other => {
            let list = PRESETS
                .iter()
                .map(|p| format!("  {:<16} {}", p.name, p.about))
                .collect::<Vec<_>>()
                .join("\n");
            bail!("unknown preset '{other}'. Available presets:\n{list}")
        }
    }
}

/// MobileNet-style depthwise-separable stack on a 3×32×32 input:
/// strided dense stem, then depthwise/pointwise pairs (one depthwise
/// strided), average pooling, and a pointwise classifier head.
fn mobilenet_mini(rng: &mut Rng) -> Result<Net> {
    let layers = vec![
        // Stem: 3 -> 8, stride 2, pad 1 (32 -> 16).
        Layer::conv(GenConvShape::new(3, 8, 32, 32, 3, 3, 2, 1, 1)?, true, 4, rng)?,
        // dw/pw pair at 16x16.
        Layer::depthwise(8, 16, 16, 1, 1, true, 4, rng)?,
        Layer::pointwise(8, 16, 16, 16, true, 4, rng)?,
        // Strided depthwise (16 -> 8) + pw expansion.
        Layer::depthwise(16, 16, 16, 2, 1, true, 4, rng)?,
        Layer::pointwise(16, 32, 8, 8, true, 4, rng)?,
        // Pool + classifier head.
        Layer::avgpool(2, 2),
        Layer::pointwise(32, 10, 4, 4, false, 4, rng)?,
    ];
    Ok(Net { name: "mobilenet-mini".into(), input_dims: (3, 32, 32), layers })
}

/// The paper's baseline layer as a single-layer network: lowered, it
/// submits exactly `ConvShape::baseline()` — same engine, cache and
/// planner keys as every figure driver.
fn paper_baseline(rng: &mut Rng) -> Result<Net> {
    let layers =
        vec![Layer::conv(GenConvShape::new(16, 16, 18, 18, 3, 3, 1, 0, 1)?, false, 4, rng)?];
    Ok(Net { name: "paper-baseline".into(), input_dims: (16, 18, 18), layers })
}

/// A small VGG-flavored stack on 3×16×16: padded stride-1 convs with
/// maxpool downsampling, finished by a strided conv.
fn vgg_mini(rng: &mut Rng) -> Result<Net> {
    let layers = vec![
        Layer::conv(GenConvShape::new(3, 8, 16, 16, 3, 3, 1, 1, 1)?, true, 4, rng)?,
        Layer::maxpool(2, 2), // 8x8
        Layer::conv(GenConvShape::new(8, 16, 8, 8, 3, 3, 1, 1, 1)?, true, 4, rng)?,
        Layer::maxpool(2, 2), // 4x4
        Layer::conv(GenConvShape::new(16, 16, 4, 4, 3, 3, 2, 1, 1)?, true, 4, rng)?, // 2x2
    ];
    Ok(Net { name: "vgg-mini".into(), input_dims: (3, 16, 16), layers })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_build_and_validate() {
        for p in PRESETS {
            let net = build(p.name, 7).unwrap();
            net.validate().unwrap();
            assert_eq!(net.name, p.name);
            assert!(net.macs() > 0);
        }
    }

    #[test]
    fn preset_dims_are_as_documented() {
        assert_eq!(build("mobilenet-mini", 1).unwrap().output_dims().unwrap(), (10, 4, 4));
        assert_eq!(build("paper-baseline", 1).unwrap().output_dims().unwrap(), (16, 16, 16));
        assert_eq!(build("vgg-mini", 1).unwrap().output_dims().unwrap(), (16, 2, 2));
    }

    #[test]
    fn paper_baseline_lowers_to_the_exact_baseline_shape() {
        let net = build("paper-baseline", 3).unwrap();
        let shape = net.layers[0].conv_shape().unwrap();
        assert_eq!(shape.to_basic(), Some(crate::conv::ConvShape::baseline()));
    }

    #[test]
    fn mobilenet_mini_covers_the_depthwise_separable_mix() {
        let net = build("mobilenet-mini", 2).unwrap();
        let kinds: Vec<&str> = net.layers.iter().map(|l| l.kind()).collect();
        assert_eq!(
            kinds,
            ["conv", "depthwise", "pointwise", "depthwise", "pointwise", "avgpool", "pointwise"]
        );
        // Strided layers present (the stem and one depthwise).
        assert_eq!(net.layers[0].conv_shape().unwrap().stride, 2);
        assert_eq!(net.layers[3].conv_shape().unwrap().stride, 2);
    }

    #[test]
    fn unknown_preset_error_lists_all_presets() {
        let err = format!("{:#}", build("resnet", 1).unwrap_err());
        for p in PRESETS {
            assert!(err.contains(p.name), "{err}");
        }
    }

    #[test]
    fn presets_are_deterministic_in_the_seed() {
        let a = build("vgg-mini", 9).unwrap();
        let b = build("vgg-mini", 9).unwrap();
        let (wa, wb) = (&a.layers[0], &b.layers[0]);
        match (wa, wb) {
            (Layer::Conv { weights: x, .. }, Layer::Conv { weights: y, .. }) => {
                assert_eq!(x.data, y.data);
            }
            _ => panic!("expected conv layers"),
        }
    }
}
