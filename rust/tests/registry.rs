//! Artifact-registry behavior: tenant isolation by fingerprint, true
//! LRU eviction, single-flight compiles, and cached failures — the
//! serving subsystem's cache contract, exercised with real compiles
//! through real engines.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use openedge_cgra::energy::EnergyModel;
use openedge_cgra::engine::{Engine, EngineBuilder};
use openedge_cgra::nn::Net;
use openedge_cgra::server::{ArtifactKey, ArtifactRegistry};

fn tiny_net(seed: u64) -> Net {
    Net::plain_stack(1, 2, 2, 6, seed).unwrap()
}

fn engine_with(model: EnergyModel) -> Engine {
    EngineBuilder::new().energy_model(model).workers(1).build().unwrap()
}

fn key_for(engine: &Engine, net: &Net) -> ArtifactKey {
    ArtifactKey { net_fp: net.fingerprint(), session_fp: engine.session_fingerprint() }
}

/// Two tenants running the *same* net under *different* energy models
/// must never share an artifact: same outputs (functional behavior is
/// model-independent), different modeled energy, zero cross-hits.
#[test]
fn energy_model_fingerprints_isolate_tenants() {
    let cold = engine_with(EnergyModel::default());
    let mut hot_model = EnergyModel::default();
    hot_model.e_mem_access_pj *= 2.0;
    hot_model.p_pe_active_mw *= 1.5;
    let hot = engine_with(hot_model);

    let net = tiny_net(3);
    let k_cold = key_for(&cold, &net);
    let k_hot = key_for(&hot, &net);
    assert_eq!(k_cold.net_fp, k_hot.net_fp, "same graph, same net fingerprint");
    assert_ne!(k_cold.session_fp, k_hot.session_fp, "different pricing sessions");

    let reg = ArtifactRegistry::new(8, 2);
    let (a_cold, hit) = reg.get_or_compile(k_cold, || cold.compile(&net)).unwrap();
    assert!(!hit);
    let (a_hot, hit) = reg.get_or_compile(k_hot, || hot.compile(&net)).unwrap();
    assert!(!hit, "a different session fingerprint must not cross-hit");
    assert!(!Arc::ptr_eq(&a_cold, &a_hot));

    // Re-fetching each tenant's key hits its own entry.
    let (again, hit) = reg.get_or_compile(k_cold, || unreachable!("must hit")).unwrap();
    assert!(hit);
    assert!(Arc::ptr_eq(&a_cold, &again));

    let s = reg.stats();
    assert_eq!((s.hits, s.misses, s.compiles, s.entries), (1, 2, 2, 2));

    // Functional isolation check: identical outputs, divergent energy.
    let input = net.random_input(8, 5);
    let mut ctx_cold = a_cold.new_ctx();
    let mut ctx_hot = a_hot.new_ctx();
    let run_cold = a_cold.run(&mut ctx_cold, &input).unwrap();
    let run_hot = a_hot.run(&mut ctx_hot, &input).unwrap();
    assert_eq!(ctx_cold.output().data, ctx_hot.output().data, "outputs are model-independent");
    assert_eq!(run_cold.total_cycles, run_hot.total_cycles, "timing is model-independent");
    assert!(
        run_hot.total_energy_uj > run_cold.total_energy_uj,
        "the hot model must price the same run higher ({} vs {})",
        run_hot.total_energy_uj,
        run_cold.total_energy_uj
    );
}

/// Capacity-2, single shard: true LRU order. Touching A makes B the
/// eviction victim when C arrives.
#[test]
fn lru_evicts_least_recently_touched() {
    let engine = engine_with(EnergyModel::default());
    let nets: Vec<Net> = (0..3).map(|i| tiny_net(10 + i)).collect();
    let keys: Vec<ArtifactKey> = nets.iter().map(|n| key_for(&engine, n)).collect();
    assert_ne!(keys[0].net_fp, keys[1].net_fp, "distinct weight seeds, distinct fingerprints");

    let reg = ArtifactRegistry::new(2, 1);
    reg.get_or_compile(keys[0], || engine.compile(&nets[0])).unwrap(); // A
    reg.get_or_compile(keys[1], || engine.compile(&nets[1])).unwrap(); // B
    reg.get_or_compile(keys[0], || unreachable!("A is resident")).unwrap(); // touch A
    reg.get_or_compile(keys[2], || engine.compile(&nets[2])).unwrap(); // C evicts B

    assert!(reg.contains(&keys[0]), "A was touched most recently before C");
    assert!(!reg.contains(&keys[1]), "B was the least recently used entry");
    assert!(reg.contains(&keys[2]));
    let s = reg.stats();
    assert_eq!((s.evictions, s.entries, s.capacity), (1, 2, 2));

    // An evicted key recompiles on return (a miss, not a hit) —
    // compile-count grows, correctness doesn't change.
    let (_, hit) = reg.get_or_compile(keys[1], || engine.compile(&nets[1])).unwrap();
    assert!(!hit);
    assert_eq!(reg.stats().compiles, 4);
}

/// Eight threads racing the same cold key: exactly one compile runs;
/// everyone gets the same `Arc`.
#[test]
fn concurrent_get_or_compile_is_single_flight() {
    let engine = engine_with(EnergyModel::default());
    let net = tiny_net(42);
    let key = key_for(&engine, &net);
    let reg = ArtifactRegistry::new(4, 2);
    let compiles = AtomicUsize::new(0);

    let artifacts: Vec<Arc<_>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                s.spawn(|| {
                    let (artifact, _) = reg
                        .get_or_compile(key, || {
                            compiles.fetch_add(1, Ordering::SeqCst);
                            engine.compile(&net)
                        })
                        .unwrap();
                    artifact
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    assert_eq!(compiles.load(Ordering::SeqCst), 1, "the compile must run exactly once");
    for a in &artifacts[1..] {
        assert!(Arc::ptr_eq(&artifacts[0], a), "every thread shares one artifact");
    }
    let s = reg.stats();
    assert_eq!(s.compiles, 1);
    assert_eq!(s.hits + s.misses, 8);
    assert_eq!(s.misses, 1, "one thread created the cell; the rest joined it");
}

/// Deterministic compile failures are cached: a memory-bound net fails
/// once and replays the error without recompiling.
#[test]
fn compile_failures_are_cached() {
    let engine = engine_with(EnergyModel::default());
    // 16ch 64x64 stride-1 valid conv blows the 4 KiB memory bound.
    let doomed = Net::plain_stack(1, 16, 16, 66, 1).unwrap();
    let key = key_for(&engine, &doomed);
    let reg = ArtifactRegistry::new(4, 1);

    let attempts = AtomicUsize::new(0);
    let mut try_once = || {
        reg.get_or_compile(key, || {
            attempts.fetch_add(1, Ordering::SeqCst);
            engine.compile(&doomed)
        })
    };
    assert!(try_once().is_err());
    assert!(try_once().is_err(), "the cached failure must replay as an error");
    assert_eq!(attempts.load(Ordering::SeqCst), 1, "a doomed net compiles exactly once");
}

/// The net fingerprint's semantics: weights matter, cosmetic names
/// don't, and regeneration with the same seed is stable.
#[test]
fn net_fingerprint_semantics() {
    let a = tiny_net(3);
    let b = tiny_net(3);
    assert_eq!(a.fingerprint(), b.fingerprint(), "same seed, same graph, same fingerprint");

    let mut renamed = tiny_net(3);
    renamed.name = "some other label".to_string();
    assert_eq!(
        a.fingerprint(),
        renamed.fingerprint(),
        "the display name is cosmetic, not identity"
    );

    let other_weights = tiny_net(4);
    assert_ne!(
        a.fingerprint(),
        other_weights.fingerprint(),
        "different weights are a different artifact"
    );
}
