//! The compile-once / run-many **counter contract**: a warm
//! `CompiledNet::run` performs zero program building, zero µop
//! decoding, zero planner work and zero arena allocation — asserted
//! against the process-wide [`RunCounters`], not assumed.
//!
//! This file deliberately holds a single `#[test]`: the counters are
//! process-wide, so any concurrently running test in the same binary
//! would move them. Other integration binaries are separate processes
//! and cannot interfere.

use openedge_cgra::engine::{CompiledNet, EngineBuilder, RunCounters};
use openedge_cgra::nn;
use openedge_cgra::obs;

#[test]
fn warm_compiled_runs_do_zero_compile_side_work() {
    let engine = EngineBuilder::new().workers(2).private_cache().build().unwrap();
    let net = nn::build_preset("mobilenet-mini", 7).unwrap();

    // Compile-side work happens here — and the counters prove it.
    let before_compile = RunCounters::snapshot(&engine);
    let compiled = engine.compile(&net).unwrap();
    let after_compile = RunCounters::snapshot(&engine);
    assert!(
        after_compile.program_builds > before_compile.program_builds,
        "compile must build launch programs"
    );
    assert!(
        after_compile.uop_decodes > before_compile.uop_decodes,
        "compile must decode programs into the µop IR"
    );
    assert!(
        after_compile.planner_estimates > before_compile.planner_estimates,
        "compile must resolve Auto mappings through the planner"
    );

    // Context creation is the one allocating step of the warm path.
    let mut ctx = compiled.new_ctx();
    let after_ctx = RunCounters::snapshot(&engine);
    assert!(
        after_ctx.arena_allocs > after_compile.arena_allocs,
        "context creation allocates the arena"
    );

    // Warm runs: several inferences over distinct inputs, verified and
    // unverified, through one shared context.
    let warmup = net.random_input(8, 1);
    let first = compiled.run_verified(&mut ctx, &warmup).unwrap();
    assert_eq!(first.exact, Some(true), "the artifact must stay golden-exact");

    let warm_before = RunCounters::snapshot(&engine);
    let mut last_cycles = 0;
    for seed in 2..6u64 {
        let input = net.random_input(8, seed);
        let run = compiled.run(&mut ctx, &input).unwrap();
        assert!(run.total_cycles > 0);
        last_cycles = run.total_cycles;
    }
    let warm_after = RunCounters::snapshot(&engine);

    assert_eq!(
        warm_after, warm_before,
        "a warm CompiledNet::run must perform no program building, no µop \
         decoding, no planner calls and no arena allocation"
    );
    // Timing is data-independent: every inference costs the same
    // modeled cycles.
    assert_eq!(last_cycles, first.total_cycles);

    // A second context allocates again (per-worker arenas), but its
    // warm runs are clean too.
    let mut ctx2 = compiled.new_ctx();
    let mid = RunCounters::snapshot(&engine);
    assert!(mid.arena_allocs > warm_after.arena_allocs);
    let run = compiled.run(&mut ctx2, &warmup).unwrap();
    assert_eq!(run.total_cycles, first.total_cycles);
    let end = RunCounters::snapshot(&engine);
    assert_eq!(end, mid, "warm runs on a fresh context are also clean");

    // The batched path honors the same contract (DESIGN.md §9): a
    // batch context allocates once at creation, and warm `run_batch`
    // calls — full and ragged — do zero builds, decodes, planner calls
    // and arena allocations.
    let bctx_before = RunCounters::snapshot(&engine);
    let mut bctx = compiled.new_batch_ctx(3);
    let bctx_after = RunCounters::snapshot(&engine);
    assert!(
        bctx_after.arena_allocs > bctx_before.arena_allocs,
        "batch context creation allocates the lane-major arena"
    );

    let inputs: Vec<_> = (0..3u64).map(|l| net.random_input(8, 10 + l)).collect();
    let warm_batch_before = RunCounters::snapshot(&engine);
    let brun = compiled.run_batch(&mut bctx, &inputs).unwrap();
    let ragged = compiled.run_batch(&mut bctx, &inputs[..2]).unwrap();
    let warm_batch_after = RunCounters::snapshot(&engine);
    assert_eq!(
        warm_batch_after, warm_batch_before,
        "a warm CompiledNet::run_batch must perform no program building, no µop \
         decoding, no planner calls and no arena allocation"
    );
    // Per-inference modeled timing matches the scalar path exactly.
    assert_eq!(brun.total_cycles, first.total_cycles);
    assert_eq!(ragged.total_cycles, first.total_cycles);

    // Tracing gates (DESIGN.md §11). Everything above ran with tracing
    // *disabled* — pin that, so the zero-work assertions double as the
    // free-when-off contract for the span tracer.
    assert!(
        !obs::trace::enabled(),
        "the counter contract above must be measured with tracing disabled"
    );

    // With tracing *enabled*, a warm run emits spans but still performs
    // zero builds, decodes, planner calls and arena allocations —
    // instrumentation observes the run, it never adds compile-side work.
    let traced_before = RunCounters::snapshot(&engine);
    let session = obs::trace::session();
    let traced_run = compiled.run(&mut ctx, &warmup).unwrap();
    let trace = session.finish();
    let traced_after = RunCounters::snapshot(&engine);
    assert_eq!(
        traced_after, traced_before,
        "a traced warm run must still do zero compile-side work"
    );
    assert_eq!(traced_run.total_cycles, first.total_cycles);
    for cat in ["engine", "layer", "kernel", "walk"] {
        assert!(
            trace.events.iter().any(|e| e.cat == cat),
            "traced warm run must emit at least one '{cat}' span"
        );
    }
    assert!(!obs::trace::enabled(), "finishing the session must disable tracing");

    // Profiling gates (DESIGN.md §12): the cycle-attribution profiler
    // honors the same contract as the tracer. Everything above ran
    // unprofiled (no attribution attached), and a profiled warm run
    // does zero compile-side work while reproducing the modeled
    // numbers bit for bit — the profiler observes, it never perturbs.
    assert!(!obs::profile::enabled());
    assert!(
        first.profile.is_none() && traced_run.profile.is_none(),
        "without a profiling session, runs must not attach attribution"
    );
    let prof_before = RunCounters::snapshot(&engine);
    let psession = obs::profile::session();
    let profiled_run = compiled.run(&mut ctx, &warmup).unwrap();
    let profile = psession.finish();
    let prof_after = RunCounters::snapshot(&engine);
    assert_eq!(
        prof_after, prof_before,
        "a profiled warm run must still do zero compile-side work"
    );
    assert_eq!(
        profiled_run.total_cycles, first.total_cycles,
        "attribution must not change the modeled cycle count"
    );
    assert_eq!(
        profiled_run.total_energy_uj.to_bits(),
        first.total_energy_uj.to_bits(),
        "attribution must not change the modeled energy, bit for bit"
    );
    let d = profiled_run.profile.expect("a profiled run attaches its walk attribution");
    assert!(d.walks > 0 && d.cycles > 0);
    assert_eq!(
        d.class_cycles.iter().sum::<u64>(),
        d.cycles,
        "bottleneck classes must account for every walk cycle exactly"
    );
    assert_eq!(profile.total.cycles, d.cycles, "the session aggregate saw the same walks");
    assert!(!obs::profile::enabled(), "finishing the session must disable profiling");

    // AOT artifact loads (DESIGN.md §13) extend the contract to disk:
    // `CompiledNet::load` is a validated copy, not a recompile — the
    // load itself moves NONE of the counters (no program builds, no
    // µop decodes, no planner calls, no arena allocation), and warm
    // runs on the loaded artifact reproduce the freshly compiled
    // artifact's outputs, cycles and energy bit for bit.
    let dir = std::env::temp_dir().join(format!("cgra-counters-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("mobilenet-mini.cgrart");
    let saved = compiled.save(&path).unwrap();
    assert_eq!(saved.net_fp, net.fingerprint());
    assert_eq!(saved.session_fp, engine.session_fingerprint());

    let load_before = RunCounters::snapshot(&engine);
    let (loaded, info) = CompiledNet::load(&engine, &path).unwrap();
    let load_after = RunCounters::snapshot(&engine);
    assert_eq!(
        load_after, load_before,
        "loading an artifact must perform no program building, no µop decoding, \
         no planner calls and no arena allocation — it is a validated copy"
    );
    assert_eq!(info, saved, "load reports the identity save recorded");

    let fresh_output = ctx.output().clone(); // ctx last ran `warmup`
    let mut lctx = loaded.new_ctx();
    let lctx_after = RunCounters::snapshot(&engine);
    assert!(lctx_after.arena_allocs > load_after.arena_allocs, "contexts still allocate");

    let lwarm_before = RunCounters::snapshot(&engine);
    let lrun = loaded.run(&mut lctx, &warmup).unwrap();
    let lwarm_after = RunCounters::snapshot(&engine);
    assert_eq!(
        lwarm_after, lwarm_before,
        "a warm run on a LOADED artifact must also do zero compile-side work"
    );
    assert_eq!(lrun.total_cycles, first.total_cycles, "cycles bit-identical after round trip");
    assert_eq!(
        lrun.total_energy_uj.to_bits(),
        first.total_energy_uj.to_bits(),
        "energy bit-identical after round trip"
    );
    assert_eq!(lctx.output().data, fresh_output.data, "outputs bit-identical after round trip");

    // The same load contract holds across the preset grid.
    for preset in ["vgg-mini", "paper-baseline"] {
        let pnet = nn::build_preset(preset, 7).unwrap();
        let pcompiled = engine.compile(&pnet).unwrap();
        let ppath = dir.join(format!("{preset}.cgrart"));
        pcompiled.save(&ppath).unwrap();
        let before = RunCounters::snapshot(&engine);
        let (ploaded, _) = CompiledNet::load(&engine, &ppath).unwrap();
        assert_eq!(
            RunCounters::snapshot(&engine),
            before,
            "loading the {preset} artifact must do zero compile-side work"
        );
        let input = pnet.random_input(8, 3);
        let (mut ca, mut cb) = (pcompiled.new_ctx(), ploaded.new_ctx());
        let ra = pcompiled.run(&mut ca, &input).unwrap();
        let warm = RunCounters::snapshot(&engine);
        let rb = ploaded.run(&mut cb, &input).unwrap();
        assert_eq!(RunCounters::snapshot(&engine), warm, "{preset}: loaded warm run clean");
        assert_eq!(ra.total_cycles, rb.total_cycles, "{preset}: cycles");
        assert_eq!(ra.total_energy_uj.to_bits(), rb.total_energy_uj.to_bits(), "{preset}: uJ");
        assert_eq!(ca.output().data, cb.output().data, "{preset}: outputs");
    }
    std::fs::remove_dir_all(&dir).ok();
}
