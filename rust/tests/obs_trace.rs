//! Integration tests for the tracing subsystem (DESIGN.md §11): a
//! compiled inference traced end to end produces Chrome trace-event
//! JSON whose spans nest correctly — every layer span inside the
//! inference span, every µop-walk span inside a kernel span — and the
//! recorder holds up under concurrent recording from many threads.
//!
//! Sessions serialize on a process-wide lock, so the `#[test]`s here
//! may run in parallel without interleaving each other's events.

use openedge_cgra::engine::EngineBuilder;
use openedge_cgra::nn::Net;
use openedge_cgra::obs::trace::{self, TraceEvent};

/// `child` lies within `parent` on the same thread timeline.
fn contained(child: &TraceEvent, parent: &TraceEvent) -> bool {
    child.tid == parent.tid
        && child.ts_ns >= parent.ts_ns
        && child.ts_ns + child.dur_ns <= parent.ts_ns + parent.dur_ns
}

#[test]
fn compiled_run_trace_nests_and_exports() {
    let engine = EngineBuilder::new().private_cache().build().unwrap();
    let net = Net::plain_stack(2, 2, 4, 8, 11).unwrap();
    let compiled = engine.compile_owned(net).unwrap();
    let mut ctx = compiled.new_ctx();
    let input = compiled.net().random_input(8, 3);
    // Warm up outside the session so the trace is the steady state.
    compiled.run(&mut ctx, &input).unwrap();

    let session = trace::session();
    compiled.run(&mut ctx, &input).unwrap();
    let t = session.finish();
    assert_eq!(t.dropped, 0);

    // Exactly one inference span; every compiled layer has a span
    // nested inside it.
    let infers: Vec<_> = t.events.iter().filter(|e| e.cat == "engine").collect();
    assert_eq!(infers.len(), 1, "one traced run, one inference span");
    let infer = infers[0];
    assert!(infer.name.starts_with("infer:"), "{}", infer.name);
    let layers: Vec<_> = t.events.iter().filter(|e| e.cat == "layer").collect();
    assert_eq!(layers.len(), compiled.layer_count(), "one span per compiled layer");
    for (i, l) in layers.iter().enumerate() {
        assert!(
            l.name.starts_with(&format!("L{i}:")),
            "layer spans complete in execution order, got '{}' at {i}",
            l.name
        );
        assert!(contained(l, infer), "layer span '{}' must nest in the inference span", l.name);
    }

    // Kernel spans nest in layer spans; walk spans nest in kernel
    // spans and carry the op-class cycle attribution.
    let kernels: Vec<_> = t.events.iter().filter(|e| e.cat == "kernel").collect();
    let walks: Vec<_> = t.events.iter().filter(|e| e.cat == "walk").collect();
    assert!(!kernels.is_empty() && !walks.is_empty());
    for k in &kernels {
        assert!(
            layers.iter().any(|l| contained(k, l)),
            "kernel span '{}' must nest in a layer span",
            k.name
        );
    }
    for w in &walks {
        assert!(w.name.starts_with("walk:"), "{}", w.name);
        assert!(
            kernels.iter().any(|k| contained(w, k)),
            "walk span '{}' must nest in a kernel span",
            w.name
        );
        let cycles = w
            .args
            .iter()
            .find(|(k, _)| *k == "cycles")
            .and_then(|(_, v)| v.as_i64())
            .expect("walk spans carry modeled cycles");
        assert!(cycles > 0);
        // The Figure-3 class attribution sums to the walk's cycles.
        let class_sum: i64 = ["load", "mul", "sum", "store", "other", "nop"]
            .iter()
            .map(|c| {
                w.args
                    .iter()
                    .find(|(k, _)| k == c)
                    .and_then(|(_, v)| v.as_i64())
                    .expect("walk spans carry every op class")
            })
            .sum();
        assert_eq!(class_sum, cycles, "op-class attribution must sum to walk cycles");
    }

    // The Chrome export round-trips through the crate's own JSON
    // parser and keeps the complete-event shape.
    let doc = t.to_chrome_json();
    let back = openedge_cgra::util::json::parse(&doc.to_string_compact()).unwrap();
    let events = back.get("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(events.len(), t.events.len());
    for e in events {
        assert_eq!(e.req_str("ph").unwrap(), "X");
        assert_eq!(e.req_i64("pid").unwrap(), 1);
        assert!(e.get("ts").unwrap().as_f64().unwrap() >= 0.0);
        assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
    }
    assert_eq!(
        back.get("otherData").unwrap().req_i64("dropped_events").unwrap(),
        0
    );
}

#[test]
fn concurrent_recording_keeps_per_thread_nesting() {
    const THREADS: usize = 8;
    let session = trace::session();
    let handles: Vec<_> = (0..THREADS)
        .map(|i| {
            std::thread::spawn(move || {
                let mut parent = trace::span_dyn("test", || format!("parent{i}"));
                parent.arg("thread", i);
                for _ in 0..3 {
                    let _child = trace::span("test", "child");
                    std::hint::black_box(0u64);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let t = session.finish();
    assert_eq!(t.dropped, 0);
    assert_eq!(t.events.len(), THREADS * 4);

    let parents: Vec<_> = t.events.iter().filter(|e| e.name.starts_with("parent")).collect();
    assert_eq!(parents.len(), THREADS);
    let tids: std::collections::BTreeSet<u64> = parents.iter().map(|p| p.tid).collect();
    assert_eq!(tids.len(), THREADS, "each thread draws a distinct tid");
    for child in t.events.iter().filter(|e| e.name == "child") {
        let parent = parents
            .iter()
            .find(|p| p.tid == child.tid)
            .expect("every child's thread has a parent span");
        assert!(contained(child, parent), "child must nest in its own thread's parent");
    }
}

#[test]
fn histograms_record_concurrently_without_loss() {
    const THREADS: u64 = 8;
    const PER_THREAD: u64 = 1000;
    let h = std::sync::Arc::new(openedge_cgra::obs::metrics::Histogram::new());
    let handles: Vec<_> = (0..THREADS)
        .map(|i| {
            let h = h.clone();
            std::thread::spawn(move || {
                for v in 0..PER_THREAD {
                    h.record(i * PER_THREAD + v);
                }
            })
        })
        .collect();
    for t in handles {
        t.join().unwrap();
    }
    let s = h.summary();
    assert_eq!(s.count, THREADS * PER_THREAD, "no sample lost under contention");
    let n = THREADS * PER_THREAD;
    assert_eq!(s.sum, n * (n - 1) / 2, "exact sum survives concurrent recording");
    assert_eq!(s.min, 0);
    assert_eq!(s.max, n - 1);
    assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
}
