//! Integration tests of the compile-once / run-many artifact:
//! compiled-vs-interpreted equivalence across the stride / pad / groups
//! grid and every preset, run-to-run determinism, the legacy
//! `Engine::run_network` reroute, and `Arc<CompiledNet>` sharing across
//! the worker pool.
//!
//! The kernel-level bit-exactness anchor (prebuilt replay ≡ the legacy
//! per-call kernel drivers, per mapping) lives in
//! `src/kernels/prebuilt.rs`; these tests pin the network-level
//! contract on top.

use std::sync::Arc;

use openedge_cgra::conv::GenConvShape;
use openedge_cgra::coordinator::{golden_network as conv_golden_network, run_jobs, ConvNet};
use openedge_cgra::engine::{Engine, EngineBuilder};
use openedge_cgra::nn::{self, Layer, Net};
use openedge_cgra::prop::Rng;

fn engine() -> Engine {
    EngineBuilder::new().workers(2).private_cache().build().unwrap()
}

/// A 2-layer net exercising one (stride, pad, groups) combination:
/// a generalized conv into a depthwise layer.
fn grid_net(stride: usize, pad: usize, groups: usize, seed: u64) -> Net {
    let mut rng = Rng::new(seed);
    let (c, k, hw) = (4, 8, 9);
    let shape = GenConvShape::new(c, k, hw, hw, 3, 3, stride, pad, groups).unwrap();
    let (oc, oh, ow) = (shape.k, shape.ox(), shape.oy());
    let conv = Layer::conv(shape, true, 4, &mut rng).unwrap();
    let dw = Layer::depthwise(oc, oh, ow, 1, 1, false, 4, &mut rng).unwrap();
    Net {
        name: format!("grid-s{stride}p{pad}g{groups}"),
        input_dims: (c, hw, hw),
        layers: vec![conv, dw],
    }
}

/// Property: across the stride × pad × groups grid, `CompiledNet::run`
/// is bit-exact with the `nn::exec` path — same outputs, same cycles,
/// same energy (bitwise), per layer — and deterministic across warm
/// replays.
#[test]
fn prop_compiled_matches_exec_across_grid() {
    let engine = engine();
    let mut cases = 0;
    for &stride in &[1usize, 2] {
        for &pad in &[0usize, 1] {
            for &groups in &[1usize, 2, 4] {
                let net = grid_net(stride, pad, groups, 31 + cases);
                let input = net.random_input(10, 5 + cases);

                let exec = nn::run_network(&engine, &net, &input).unwrap();
                assert!(exec.exact, "{}: exec must match golden", net.name);

                let compiled = engine.compile(&net).unwrap();
                let mut ctx = compiled.new_ctx();
                let a = compiled.run(&mut ctx, &input).unwrap();
                assert_eq!(
                    ctx.output().data,
                    exec.output.data,
                    "{}: compiled output",
                    net.name
                );
                assert_eq!(a.total_cycles, exec.total_cycles, "{}", net.name);
                assert_eq!(
                    a.total_energy_uj.to_bits(),
                    exec.total_energy_uj.to_bits(),
                    "{}",
                    net.name
                );
                for (lr, er) in a.layers.iter().zip(exec.layers.iter()) {
                    assert_eq!(lr.cycles, er.cycles, "{} layer {}", net.name, er.index);
                    assert_eq!(
                        lr.conv_cycles, er.conv_cycles,
                        "{} layer {}",
                        net.name, er.index
                    );
                    assert_eq!(
                        lr.host_cycles, er.host_cycles,
                        "{} layer {}",
                        net.name, er.index
                    );
                    assert_eq!(
                        lr.energy_uj.to_bits(),
                        er.energy_uj.to_bits(),
                        "{} layer {}",
                        net.name,
                        er.index
                    );
                    assert_eq!(lr.launches, er.launches, "{} layer {}", net.name, er.index);
                    assert_eq!(lr.mapping, er.mapping, "{} layer {}", net.name, er.index);
                }
                // Warm replay is deterministic and allocation-stable.
                let b = compiled.run(&mut ctx, &input).unwrap();
                assert_eq!(b.total_cycles, a.total_cycles, "{}", net.name);
                assert_eq!(
                    b.total_energy_uj.to_bits(),
                    a.total_energy_uj.to_bits(),
                    "{}",
                    net.name
                );
                assert_eq!(ctx.output().data, exec.output.data, "{}", net.name);
                cases += 1;
            }
        }
    }
    assert_eq!(cases, 12, "full grid covered");
}

/// Every preset compiles, and the compiled run matches the interpreted
/// wrapper bit-for-bit while the verified mode confirms golden
/// exactness per layer.
#[test]
fn presets_compile_and_match_exec() {
    let engine = engine();
    for preset in ["mobilenet-mini", "paper-baseline", "vgg-mini"] {
        let net = nn::build_preset(preset, 7).unwrap();
        let input = net.random_input(8, 7);
        let exec = nn::run_network(&engine, &net, &input).unwrap();
        assert!(exec.exact, "{preset}");

        let compiled = engine.compile(&net).unwrap();
        let mut ctx = compiled.new_ctx();
        let run = compiled.run_verified(&mut ctx, &input).unwrap();
        assert_eq!(run.exact, Some(true), "{preset}: verified mode");
        assert_eq!(ctx.output().data, exec.output.data, "{preset}");
        assert_eq!(run.total_cycles, exec.total_cycles, "{preset}");
        assert_eq!(
            run.total_energy_uj.to_bits(),
            exec.total_energy_uj.to_bits(),
            "{preset}"
        );
        // Per-layer rows agree (cycles decompose identically).
        for (lr, er) in run.layers.iter().zip(exec.layers.iter()) {
            assert_eq!(lr.cycles, er.cycles, "{preset} layer {}", er.index);
            assert_eq!(lr.conv_cycles, er.conv_cycles, "{preset} layer {}", er.index);
            assert_eq!(lr.host_cycles, er.host_cycles, "{preset} layer {}", er.index);
            assert_eq!(lr.exact, Some(er.exact), "{preset} layer {}", er.index);
        }
        // The artifact owns pre-decoded programs for every conv layer.
        assert!(compiled.total_launches() > 0 && compiled.total_uops() > 0, "{preset}");
    }
}

/// The legacy `Engine::run_network` (ConvNet surface) routes through
/// the compiled artifact and still matches the golden chain and the
/// direct `compile_conv_net` path.
#[test]
fn conv_net_reroute_matches_golden_and_compiled() {
    let engine = engine();
    let net = ConvNet::random(3, 2, 4, 9, 9, 11);
    let input = {
        let mut rng = Rng::new(5);
        openedge_cgra::conv::random_input(&net.layers[0].shape, 8, &mut rng)
    };
    let out = engine.run_network(&net, &input).unwrap();
    let golden = conv_golden_network(&net, &input).unwrap();
    assert_eq!(out.output.data, golden.data);
    assert_eq!(out.layers.len(), 3);
    assert!(out.layers.iter().all(|r| r.latency_cycles > 0));

    let compiled = engine.compile_conv_net(&net).unwrap();
    let mut ctx = compiled.new_ctx();
    let run = compiled.run(&mut ctx, &input).unwrap();
    assert_eq!(ctx.output().data, out.output.data);
    assert_eq!(run.total_cycles, out.total_cycles);
    assert_eq!(run.total_energy_uj.to_bits(), out.total_energy_uj.to_bits());
    assert_eq!(run.relu_cycles, out.relu_cycles);
}

/// One `Arc<CompiledNet>` shared across the worker pool: every worker
/// builds its own context and replays concurrently; results are
/// bit-identical to the single-threaded reference, per input.
#[test]
fn arc_shared_artifact_serves_pool_workers_exactly() {
    let engine = engine();
    let net = nn::build_preset("mobilenet-mini", 3).unwrap();
    let compiled = Arc::new(engine.compile(&net).unwrap());

    // Single-threaded reference outputs for 8 distinct inputs.
    let inputs: Vec<_> = (0..8u64).map(|i| net.random_input(8, 100 + i)).collect();
    let mut ref_ctx = compiled.new_ctx();
    let reference: Vec<(Vec<i32>, u64)> = inputs
        .iter()
        .map(|input| {
            let run = compiled.run(&mut ref_ctx, input).unwrap();
            (ref_ctx.output().data.clone(), run.total_cycles)
        })
        .collect();

    // Fan the same inputs over 4 workers, each with its own context.
    let jobs: Vec<_> = inputs
        .iter()
        .map(|input| {
            let compiled = compiled.clone();
            move || {
                let mut ctx = compiled.new_ctx();
                let run = compiled.run_verified(&mut ctx, input).unwrap();
                assert_eq!(run.exact, Some(true));
                (ctx.output().data.clone(), run.total_cycles)
            }
        })
        .collect();
    let results = run_jobs(4, jobs);
    assert_eq!(results.len(), reference.len());
    for (i, (got, want)) in results.iter().zip(reference.iter()).enumerate() {
        assert_eq!(got.0, want.0, "input {i}: concurrent output diverged");
        assert_eq!(got.1, want.1, "input {i}: concurrent cycles diverged");
    }
}

/// Compile-time failures carry the layer context, and a compiled
/// artifact rejects inputs with the wrong dims.
#[test]
fn compile_and_run_errors_are_actionable() {
    let engine = engine();
    let mut rng = Rng::new(1);
    let net = Net {
        name: "big".into(),
        input_dims: (16, 66, 66),
        layers: vec![Layer::conv(
            GenConvShape::new(16, 16, 66, 66, 3, 3, 1, 0, 1).unwrap(),
            false,
            2,
            &mut rng,
        )
        .unwrap()],
    };
    let err = format!("{:#}", engine.compile(&net).unwrap_err());
    assert!(err.contains("layer 0") && err.contains("big"), "{err}");

    let ok = nn::build_preset("paper-baseline", 2).unwrap();
    let compiled = engine.compile(&ok).unwrap();
    let mut ctx = compiled.new_ctx();
    let bad_input = openedge_cgra::conv::TensorChw::zeros(1, 4, 4);
    let err = format!("{:#}", compiled.run(&mut ctx, &bad_input).unwrap_err());
    assert!(err.contains("expects"), "{err}");
}
