//! The admission-control **metrics-only contract**: rejecting an
//! over-deadline request consults the analytical planner and nothing
//! else — zero program builds, zero µop decodes, zero arena
//! allocations — asserted against the process-wide
//! [`RunCounters`], not assumed.
//!
//! This file deliberately holds a single `#[test]`: the counters are
//! process-wide, so any concurrently running test in the same binary
//! would move them. Other integration binaries are separate processes
//! and cannot interfere.

use openedge_cgra::engine::RunCounters;
use openedge_cgra::planner::PlanObjective;
use openedge_cgra::server::{AdmissionPolicy, Daemon, InferRequest, NetSpec, Outcome};

#[test]
fn rejection_never_simulates() {
    let daemon = Daemon::builder().workers(1).batch(1).build();
    let spec = NetSpec::Stack { depth: 1, c0: 2, k: 2, hw: 6, seed: 3 };

    // Warm everything once: tenant creation, planner memo for this
    // (net, objective), artifact compile, one real execution.
    let warm = daemon.submit(InferRequest::new("t", spec.clone())).unwrap();
    assert!(matches!(warm, Outcome::Served(_)));
    assert_eq!(daemon.registry().stats().compiles, 1);

    // From here on, an impossible-deadline rejection must be pure
    // arithmetic over already-memoized planner figures.
    let engine = daemon.tenant("t").unwrap();
    let before = RunCounters::snapshot(engine.engine());

    let mut req = InferRequest::new("t", spec);
    req.count = 4;
    req.objective = PlanObjective::Latency;
    req.deadline_us = Some(0.001);
    req.admission = Some(AdmissionPolicy::Reject);
    match daemon.submit(req).unwrap() {
        Outcome::Rejected(r) => {
            assert_eq!(r.kind, "deadline");
            assert!(r.modeled_us > r.deadline_us);
        }
        Outcome::Served(s) => panic!("an impossible deadline was admitted (count {})", s.count),
    }

    let after = RunCounters::snapshot(engine.engine());
    assert_eq!(
        after.program_builds, before.program_builds,
        "rejection must not build kernel programs"
    );
    assert_eq!(after.uop_decodes, before.uop_decodes, "rejection must not decode µops");
    assert_eq!(after.arena_allocs, before.arena_allocs, "rejection must not allocate arenas");
    // (planner_estimates is deliberately unasserted: the memoized
    // planner may count a memo lookup as an estimate.)

    // Nothing was compiled, cached, or executed for the rejected
    // request.
    let reg = daemon.registry().stats();
    assert_eq!(reg.compiles, 1, "no new compile for a rejected request");
    let stats = daemon.stats();
    assert_eq!(stats.rejected, 1);
    assert_eq!(stats.served_requests, 1, "only the warm request executed");
    daemon.shutdown();
}
