//! Cross-module integration tests: the paper's quantitative claims,
//! end-to-end through the session `Engine` (simulator + kernels +
//! energy model).

use openedge_cgra::cgra::OpClass;
use openedge_cgra::conv::{conv2d, random_input, random_weights, ConvShape};
use openedge_cgra::coordinator::{golden_network, ConvNet, SweepSpec};
use openedge_cgra::engine::{ConvRequest, Engine, EngineBuilder};
use openedge_cgra::kernels::Mapping;
use openedge_cgra::metrics::MappingReport;
use openedge_cgra::prop::Rng;

fn engine() -> Engine {
    EngineBuilder::new().workers(8).build().unwrap()
}

fn baseline_reports() -> Vec<MappingReport> {
    engine().run_all_mappings(&ConvShape::baseline(), 99).unwrap()
}

/// E3 — the headline: WP vs CPU ≈ 9.9× latency, ≈ 3.4× energy, WP at
/// ≈ 0.6 MAC/cycle and ≈ 2.5 mW. Bands are ±20% of the paper's values
/// (our substrate is a simulator, not the authors' testbed).
#[test]
fn calibration_anchors() {
    let rows = baseline_reports();
    let wp = rows.iter().find(|r| r.mapping == Mapping::Wp).unwrap();
    let cpu = rows.iter().find(|r| r.mapping == Mapping::Cpu).unwrap();

    let lat_ratio = cpu.latency_cycles as f64 / wp.latency_cycles as f64;
    assert!((7.9..11.9).contains(&lat_ratio), "latency ratio {lat_ratio:.2} vs paper 9.9");

    let e_ratio = cpu.energy_uj / wp.energy_uj;
    assert!((2.7..4.1).contains(&e_ratio), "energy ratio {e_ratio:.2} vs paper 3.4");

    assert!(
        (0.48..0.72).contains(&wp.mac_per_cycle),
        "WP {:.3} MAC/cycle vs paper ~0.6",
        wp.mac_per_cycle
    );
    assert!(
        (2.0..3.0).contains(&wp.avg_power_mw),
        "WP {:.2} mW vs paper ~2.5",
        wp.avg_power_mw
    );
}

/// Fig. 4 ordering: WP wins both energy and latency among all
/// strategies; the CPU is the latency extreme; IP is the worst CGRA
/// mapping on energy (im2col rebuild + launch storm).
#[test]
fn fig4_ordering() {
    let rows = baseline_reports();
    let get = |m: Mapping| rows.iter().find(|r| r.mapping == m).unwrap();
    let wp = get(Mapping::Wp);
    for m in [Mapping::Ip, Mapping::OpIm2col, Mapping::OpDirect, Mapping::Cpu] {
        assert!(get(m).latency_cycles > wp.latency_cycles, "{m} latency should exceed WP");
        assert!(get(m).energy_uj > wp.energy_uj, "{m} energy should exceed WP");
    }
    assert!(get(Mapping::Ip).energy_uj > get(Mapping::OpIm2col).energy_uj);
    // The paper: Im2col-OP marginally improves on Conv-OP.
    assert!(get(Mapping::OpIm2col).latency_cycles < get(Mapping::OpDirect).latency_cycles);
    // Memory dynamic energy is the discriminator (paper §3.1).
    assert!(get(Mapping::OpIm2col).energy.mem_dynamic_uj > 2.0 * wp.energy.mem_dynamic_uj);
}

/// Fig. 3 structure: WP utilization ≈ 78% main-loop class; the three
/// lane mappings share one ≈ 69% 8-instruction loop, load-dominated.
#[test]
fn fig3_utilization_and_mix() {
    let rows = baseline_reports();
    let get = |m: Mapping| rows.iter().find(|r| r.mapping == m).unwrap();
    let wp = get(Mapping::Wp);
    assert!((0.60..0.90).contains(&wp.utilization), "WP util {:.3}", wp.utilization);
    for m in [Mapping::Ip, Mapping::OpIm2col, Mapping::OpDirect] {
        let r = get(m);
        assert!(
            (0.55..0.78).contains(&r.utilization),
            "{m} utilization {:.3} vs paper's 69%",
            r.utilization
        );
        // Lane mappings: 2 loads per mul.
        let loads = r.op_mix[OpClass::Load.idx()];
        let muls = r.op_mix[OpClass::Mul.idx()];
        assert!(loads > 1.7 * muls, "{m}: loads {loads:.3} should dwarf muls {muls:.3}");
    }
    // WP is mul/sum-heavy instead.
    let wp_loads = wp.op_mix[OpClass::Load.idx()];
    let wp_mulsum = wp.op_mix[OpClass::Mul.idx()] + wp.op_mix[OpClass::Sum.idx()];
    assert!(wp_mulsum > 1.5 * wp_loads, "WP mix: mul+sum {wp_mulsum:.3} vs loads {wp_loads:.3}");
}

/// §3.2 — the parallel-dimension collapse at 17 and WP's robustness.
#[test]
fn dim_17_collapse_and_wp_robustness() {
    let e = engine();
    let run_one = |m: Mapping, shape: ConvShape| -> f64 {
        let mut rng = Rng::new(7);
        let input = random_input(&shape, 20, &mut rng);
        let weights = random_weights(&shape, 9, &mut rng);
        let res = e.submit(&ConvRequest::with_data(shape, m, input, weights)).unwrap();
        res.report.mac_per_cycle
    };
    let b = ConvShape::baseline();

    // K = 17 hurts the OP mappings hard (second tile nearly idle).
    for m in [Mapping::OpIm2col, Mapping::OpDirect] {
        let at16 = run_one(m, b);
        let at17 = run_one(m, ConvShape { k: 17, ..b });
        assert!(
            at17 < 0.62 * at16,
            "{m}: K=17 gives {at17:.3}, expected a sharp drop from {at16:.3}"
        );
    }
    // C = 17 hurts IP (15 dummy channels per lane tile).
    {
        let at16 = run_one(Mapping::Ip, b);
        let at17 = run_one(Mapping::Ip, ConvShape { c: 17, ..b });
        assert!(at17 < 0.75 * at16, "IP: C=17 gives {at17:.3} vs {at16:.3}");
    }
    // WP barely moves (no parallel-dimension tiling at all).
    {
        let at16 = run_one(Mapping::Wp, b);
        let at17 = run_one(Mapping::Wp, ConvShape { k: 17, c: 17, ..b });
        assert!(at17 > 0.90 * at16, "WP should be robust: 17/16 ratio {:.3}", at17 / at16);
    }
}

/// §3.2 — WP improves monotonically with spatial size (border + launch
/// amortization), toward the paper's 0.665 peak.
#[test]
fn wp_improves_with_spatial_size() {
    let e = engine();
    let mut prev = 0.0;
    for s in [8usize, 16, 32, 48] {
        let shape = ConvShape::new3x3(4, 4, s, s);
        let mut rng = Rng::new(11);
        let input = random_input(&shape, 10, &mut rng);
        let weights = random_weights(&shape, 9, &mut rng);
        let res = e.submit(&ConvRequest::with_data(shape, Mapping::Wp, input, weights)).unwrap();
        let mpc = res.report.mac_per_cycle;
        assert!(mpc > prev, "WP MAC/cycle should grow with Ox=Oy: {mpc:.3} at {s}");
        prev = mpc;
    }
    assert!(prev > 0.58, "WP at 48x48 should approach the paper's 0.665 peak, got {prev:.3}");
}

/// The 512 KiB memory bound rejects oversized layers for every mapping
/// (the paper's sweep bound), with an actionable error — and
/// `Mapping::Auto` reports the same bound instead of picking a
/// strategy that cannot run.
#[test]
fn memory_bound_enforced() {
    let e = engine();
    let shape = ConvShape::new3x3(16, 16, 64, 64); // 550 KB > 512 KiB
    let mut rng = Rng::new(1);
    let input = random_input(&shape, 5, &mut rng);
    let weights = random_weights(&shape, 5, &mut rng);
    for m in Mapping::CGRA {
        let err = e
            .submit(&ConvRequest::with_data(shape, m, input.clone(), weights.clone()))
            .unwrap_err();
        assert!(format!("{err:#}").contains("512"), "{m}: {err:#}");
    }
    let err =
        e.submit(&ConvRequest::with_data(shape, Mapping::Auto, input, weights)).unwrap_err();
    assert!(format!("{err:#}").contains("512"), "Auto: {err:#}");
}

/// End-to-end CNN: all conv layers on the CGRA, bit-exact against the
/// golden network, with sensible aggregate metrics.
#[test]
fn cnn_end_to_end() {
    let net = ConvNet::random(3, 3, 8, 12, 12, 42);
    let mut rng = Rng::new(43);
    let input = random_input(&net.layers[0].shape, 8, &mut rng);
    let out = engine().run_network(&net, &input).unwrap();
    let golden = golden_network(&net, &input).unwrap();
    assert_eq!(out.output.data, golden.data);
    let mpc = out.mac_per_cycle(&net);
    assert!((0.3..0.8).contains(&mpc), "network MAC/cycle {mpc:.3}");
    assert!(out.total_energy_uj > 0.0);
}

/// Deterministic outputs regardless of worker count (coordinator).
#[test]
fn sweep_deterministic_across_workers() {
    let spec = SweepSpec {
        c_values: vec![4, 17],
        k_values: vec![4],
        spatial_values: vec![],
        mappings: vec![Mapping::Wp, Mapping::OpIm2col],
        mag: 10,
        seed: 5,
    };
    let a = EngineBuilder::new().workers(1).build().unwrap().sweep(&spec).unwrap();
    let b = EngineBuilder::new().workers(7).build().unwrap().sweep(&spec).unwrap();
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(
            x.report.as_ref().map(|r| r.latency_cycles),
            y.report.as_ref().map(|r| r.latency_cycles)
        );
    }
}

/// The golden im2col path and direct path agree (conv substrate).
#[test]
fn im2col_golden_agrees_with_direct() {
    let shape = ConvShape::new3x3(3, 5, 7, 6);
    let mut rng = Rng::new(21);
    let input = random_input(&shape, 30, &mut rng);
    let weights = random_weights(&shape, 9, &mut rng);
    let direct = conv2d(&shape, &input, &weights);
    let via = openedge_cgra::conv::conv2d_im2col(
        &shape,
        &input.to_hwc(),
        &weights.to_im2col_matrix(),
    );
    assert_eq!(direct.data, via);
}

/// Energy model sanity across a full report: totals equal the sum of
/// parts.
#[test]
fn energy_decomposition_consistent() {
    let rows = baseline_reports();
    for r in &rows {
        let sum = r.energy.cgra_uj
            + r.energy.cpu_uj
            + r.energy.mem_static_uj
            + r.energy.mem_dynamic_uj;
        assert!((sum - r.energy_uj).abs() < 1e-9, "{}", r.mapping);
        assert!(r.energy_uj > 0.0);
    }
}

/// The engine's batch and sequential paths agree bit-for-bit with the
/// one-call report drivers (the migration invariant of the 0.2 API).
#[test]
fn engine_paths_agree_with_figure_drivers() {
    let e = engine();
    let shape = ConvShape::baseline();
    let batched = e.run_all_mappings(&shape, 99).unwrap();
    for (row, m) in batched.iter().zip(Mapping::ALL) {
        let single = e.submit(&ConvRequest::seeded(shape, m, 99)).unwrap();
        assert_eq!(single.report.latency_cycles, row.latency_cycles, "{m}");
        assert_eq!(single.report.energy_uj.to_bits(), row.energy_uj.to_bits(), "{m}");
    }
}
