//! Property tests on the coordinator layer (pool scheduling, sweep
//! bookkeeping, network chaining) and the JSON/infra substrate.

use openedge_cgra::cgra::CgraConfig;
use openedge_cgra::coordinator::{run_jobs, ConvNet, SweepSpec};
use openedge_cgra::kernels::Mapping;
use openedge_cgra::prop::{forall, int_in, usize_in, vec_of, Gen};
use openedge_cgra::util::json::{parse, Json};

/// Pool: arbitrary job counts × worker counts preserve order and run
/// every job exactly once.
#[test]
fn prop_pool_order_and_coverage() {
    let g = usize_in(0, 40).pair(usize_in(1, 12));
    forall("pool order/coverage", 30, &g, |&(n, workers)| {
        let jobs: Vec<_> = (0..n).map(|i| move || i * 3 + 1).collect();
        let out = run_jobs(workers, jobs);
        if out.len() != n {
            return Err(format!("{} results for {n} jobs", out.len()));
        }
        for (i, v) in out.iter().enumerate() {
            if *v != i * 3 + 1 {
                return Err(format!("slot {i} holds {v}"));
            }
        }
        Ok(())
    });
}

/// Sweep point generation: every (axis value × mapping) pair appears
/// exactly once; shapes inherit the baseline on untouched axes.
#[test]
fn prop_sweep_points_complete() {
    let g = vec_of(usize_in(1, 40), 1, 6).pair(usize_in(1, 4));
    forall("sweep point coverage", 20, &g, |(cs, n_mappings)| {
        let mappings: Vec<Mapping> = Mapping::ALL[..*n_mappings].to_vec();
        let spec = SweepSpec {
            c_values: cs.clone(),
            k_values: vec![],
            spatial_values: vec![],
            mappings: mappings.clone(),
            mag: 5,
            seed: 0,
        };
        let points = spec.points();
        if points.len() != cs.len() * mappings.len() {
            return Err(format!("{} points", points.len()));
        }
        for p in &points {
            if p.shape.k != 16 || p.shape.ox != 16 || p.shape.oy != 16 {
                return Err("baseline axes disturbed".into());
            }
            if p.shape.c != p.value {
                return Err("value/shape mismatch".into());
            }
        }
        Ok(())
    });
}

/// Random network specs always chain shapes correctly.
#[test]
fn prop_network_chaining() {
    let g = usize_in(1, 4)
        .pair(usize_in(1, 5))
        .pair(usize_in(1, 6).pair(usize_in(11, 16)));
    forall("ConvNet::random chains", 25, &g, |&((depth, c0), (k, hw))| {
        if hw < 2 * depth + 1 {
            return Ok(()); // spatial size would vanish; builder unused here
        }
        let net = ConvNet::random(depth, c0, k, hw, hw, 99);
        net.validate().map_err(|e| e.to_string())?;
        if net.layers.len() != depth {
            return Err("wrong depth".into());
        }
        if net.layers[0].shape.c != c0 {
            return Err("c0 lost".into());
        }
        if net.layers.last().unwrap().relu {
            return Err("last layer must not have ReLU".into());
        }
        Ok(())
    });
}

/// JSON roundtrip: arbitrary nested values survive serialize → parse.
#[test]
fn prop_json_roundtrip() {
    fn json_gen(depth: usize) -> Gen<Json> {
        if depth == 0 {
            int_in(-1_000_000, 1_000_000).map(|v| Json::Num(v as f64))
        } else {
            usize_in(0, 4).map(move |tag| tag).pair(json_gen(depth - 1)).map(
                move |(tag, inner)| match tag {
                    0 => Json::Null,
                    1 => Json::Bool(true),
                    2 => Json::Str("λ \"quoted\"\n".into()),
                    3 => Json::Arr(vec![inner, Json::Num(1.5)]),
                    _ => Json::obj(vec![("k", inner), ("n", Json::Num(-3.0))]),
                },
            )
        }
    }
    forall("json roundtrip", 60, &json_gen(3), |v| {
        let text = v.to_string_compact();
        let back = parse(&text).map_err(|e| e.to_string())?;
        if &back == v {
            Ok(())
        } else {
            Err(format!("roundtrip changed value: {text}"))
        }
    });
}

/// Sweep skips (memory bound) never abort the whole sweep and always
/// carry a reason.
#[test]
fn prop_sweep_skip_isolation() {
    let g = usize_in(100, 200);
    forall("sweep skip isolation", 5, &g, |&c| {
        let spec = SweepSpec {
            c_values: vec![c, 2],
            k_values: vec![],
            spatial_values: vec![],
            mappings: vec![Mapping::Wp],
            mag: 3,
            seed: 0,
        };
        let mut cfg = CgraConfig::default();
        cfg.mem_words = 16384; // small memory: the big point must skip
        let rows = openedge_cgra::engine::EngineBuilder::new()
            .config(cfg)
            .workers(2)
            .build()
            .map_err(|e| e.to_string())?
            .sweep(&spec)
            .map_err(|e| e.to_string())?;
        if rows.len() != 2 {
            return Err("row count".into());
        }
        let big = &rows[0];
        let small = &rows[1];
        if big.report.is_some() || big.skipped.is_none() {
            return Err("oversized point must be skipped with a reason".into());
        }
        if small.report.is_none() {
            return Err("small point must still run".into());
        }
        Ok(())
    });
}
