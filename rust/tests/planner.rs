//! Planner integration tests: the tentpole's acceptance criteria.
//!
//! - The cost model must track the decoded simulator within 5 % mean
//!   absolute latency error over a sweep grid (the `cgra plan
//!   --validate` protocol; CI runs the same check through the CLI).
//! - Cost-model-backed `Mapping::Auto` must agree with the pre-planner
//!   threshold policy — Conv-WP — on every in-bound shape of the
//!   paper's Fig. 5 grid (the differential test: probes only, no full
//!   simulations, so the whole grid stays cheap).

use openedge_cgra::conv::{random_input, ConvShape};
use openedge_cgra::coordinator::{ConvNet, SweepSpec};
use openedge_cgra::engine::{ConvRequest, Engine, EngineBuilder};
use openedge_cgra::kernels::Mapping;
use openedge_cgra::planner::{validate, PlanObjective};
use openedge_cgra::prop::Rng;

fn engine() -> Engine {
    EngineBuilder::new().workers(4).private_cache().build().unwrap()
}

/// Predicted-vs-simulated error on a reduced grid that includes the
/// odd-valued (worst bank-alignment) shapes. The 5 % bound is the
/// tentpole's acceptance criterion; in practice the residual is far
/// smaller because probe launches replay the exact step sequences.
#[test]
fn planner_tracks_simulator_within_bound_on_odd_and_even_shapes() {
    let e = engine();
    let spec = SweepSpec {
        c_values: vec![16, 17],
        k_values: vec![16, 17],
        spatial_values: vec![16, 17],
        mappings: Mapping::ALL.to_vec(),
        mag: 20,
        seed: 0xf15_5eed,
    };
    let report = validate(&e, &spec).unwrap();
    assert!(report.rows.len() >= 25, "expected a populated grid, got {}", report.rows.len());
    assert_eq!(report.bound_mismatches, 0, "planner and simulator must agree on feasibility");
    assert!(
        report.mean_abs_latency_err_pct <= 5.0,
        "mean |latency err| {:.3}% exceeds the 5% acceptance bound",
        report.mean_abs_latency_err_pct
    );
    assert!(
        report.mean_abs_energy_err_pct <= 5.0,
        "mean |energy err| {:.3}% exceeds 5%",
        report.mean_abs_energy_err_pct
    );
    // The planner must be calibrating from far fewer launches than the
    // simulations executed — that is the entire point.
    assert!(
        report.probe_launches * 10 <= report.simulated_launches,
        "probes {} vs simulated {}",
        report.probe_launches,
        report.simulated_launches
    );
    // CPU rows are closed form: exactly zero error.
    for r in report.rows.iter().filter(|r| r.mapping == Mapping::Cpu) {
        assert_eq!(r.latency_err_pct, 0.0, "CPU row {}{}", r.axis, r.value);
    }
}

/// The differential acceptance test: over the paper's full Fig. 5
/// grid, the cost-model `Auto` and the old threshold policy choose the
/// same mapping — Conv-WP — on every in-bound shape. Only calibration
/// probes run here (a few launches per shape), never full convolutions.
#[test]
fn cost_backed_auto_selects_wp_across_the_paper_grid() {
    let e = engine();
    let cfg = e.config().clone();
    let mut shapes_checked = 0;
    for point in SweepSpec::paper().points() {
        if point.mapping != Mapping::Wp {
            continue; // one visit per shape; the mapping field is irrelevant here
        }
        let shape = point.shape;
        let threshold = match Mapping::Auto.resolve(&shape, &cfg) {
            Ok((m, _reason)) => m,
            Err(_) => continue, // out of bound: both policies refuse (checked elsewhere)
        };
        let est = e.planner().choose(&shape).unwrap();
        assert_eq!(est.mapping, threshold, "policies disagree on {shape}");
        assert_eq!(est.mapping, Mapping::Wp, "the paper's conclusion on {shape}");
        shapes_checked += 1;
    }
    assert!(shapes_checked >= 40, "only {shapes_checked} in-bound grid shapes checked");
}

/// submit_planned answers metrics-only requests from the model; the
/// answer must be close to a real simulation of the same request, and
/// repeats must be pure memo lookups.
#[test]
fn submit_planned_matches_simulation_closely() {
    let e = engine();
    let req = ConvRequest::seeded(ConvShape::new3x3(4, 4, 6, 6), Mapping::Auto, 11).relu(true);
    let planned = e.submit_planned(&req).unwrap();
    assert!(planned.auto.is_some());
    let sim = e.submit(&req).unwrap();
    assert_eq!(planned.mapping, sim.mapping, "both paths resolve Auto identically");
    let (p, s) =
        (planned.estimate.report.latency_cycles as f64, sim.report.latency_cycles as f64);
    assert!(((p - s) / s).abs() <= 0.05, "planned {p} vs simulated {s}");
    // The requested ReLU is charged identically on both paths.
    assert_eq!(planned.relu_cycles, sim.relu_cycles);
    assert_eq!(planned.relu_energy_uj.to_bits(), sim.relu_energy_uj.to_bits());
    let (pt, st) = (planned.total_cycles() as f64, sim.total_cycles() as f64);
    assert!(((pt - st) / st).abs() <= 0.05, "planned total {pt} vs simulated total {st}");
    let probes = e.planner().stats().probe_launches;
    let again = e.submit_planned(&req).unwrap();
    assert_eq!(e.planner().stats().probe_launches, probes, "repeat plans must not probe");
    assert_eq!(again.estimate.report.latency_cycles, planned.estimate.report.latency_cycles);
}

/// Network planning end to end: plan, apply, simulate, compare totals.
#[test]
fn network_plan_predicts_the_simulated_inference() {
    let e = engine();
    let mut net = ConvNet::random(3, 2, 5, 10, 10, 9);
    let plan = e.plan_network(&net, PlanObjective::Latency).unwrap();
    assert_eq!(plan.layers.len(), 3);
    assert!(plan.total_cycles > 0 && plan.total_energy_uj > 0.0);
    plan.apply(&mut net).unwrap();
    assert!(net.layers.iter().all(|l| !l.mapping.is_auto()));
    let mut rng = Rng::new(3);
    let input = random_input(&net.layers[0].shape, 6, &mut rng);
    let out = e.run_network(&net, &input).unwrap();
    let (p, s) = (plan.total_cycles as f64, out.total_cycles as f64);
    assert!(((p - s) / s).abs() <= 0.05, "planned {p} vs simulated {s} cycles");
    let (pe, se) = (plan.total_energy_uj, out.total_energy_uj);
    assert!(((pe - se) / se).abs() <= 0.05, "planned {pe} vs simulated {se} uJ");
}

/// An energy-objective plan never predicts more energy than a
/// latency-objective plan of the same network.
#[test]
fn energy_objective_never_costs_more_energy() {
    let e = engine();
    let net = ConvNet::random(2, 3, 4, 9, 9, 21);
    let by_latency = e.plan_network(&net, PlanObjective::Latency).unwrap();
    let by_energy = e.plan_network(&net, PlanObjective::Energy).unwrap();
    assert!(by_energy.total_energy_uj <= by_latency.total_energy_uj + 1e-9);
}
