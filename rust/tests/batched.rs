//! Integration tests of the data-parallel batched executor
//! (DESIGN.md §9): `CompiledNet::run_batch` must be indistinguishable
//! from running each lane through the scalar `CompiledNet::run` —
//! bit-identical outputs per lane, bit-identical modeled
//! per-inference cycles and energy (down to the f64 bits), per layer —
//! across the stride / pad / groups lowering grid and a real preset.
//! Also pinned: the B=1 degeneracy, the ragged final chunk, the golden
//! debug mode, and the argument validation.

use openedge_cgra::conv::{GenConvShape, TensorChw};
use openedge_cgra::engine::{CompiledNet, Engine, EngineBuilder, InferRun};
use openedge_cgra::nn::{self, Layer, Net};
use openedge_cgra::prop::Rng;

fn engine() -> Engine {
    EngineBuilder::new().workers(2).private_cache().build().unwrap()
}

/// A 2-layer net exercising one (stride, pad, groups) combination:
/// a generalized conv into a depthwise layer (same grid as
/// `tests/compiled.rs`, so the scalar reference is itself pinned
/// against the golden model elsewhere).
fn grid_net(stride: usize, pad: usize, groups: usize, seed: u64) -> Net {
    let mut rng = Rng::new(seed);
    let (c, k, hw) = (4, 8, 9);
    let shape = GenConvShape::new(c, k, hw, hw, 3, 3, stride, pad, groups).unwrap();
    let (oc, oh, ow) = (shape.k, shape.ox(), shape.oy());
    let conv = Layer::conv(shape, true, 4, &mut rng).unwrap();
    let dw = Layer::depthwise(oc, oh, ow, 1, 1, false, 4, &mut rng).unwrap();
    Net {
        name: format!("grid-s{stride}p{pad}g{groups}"),
        input_dims: (c, hw, hw),
        layers: vec![conv, dw],
    }
}

/// Assert two per-inference results are bit-equal, layer by layer.
fn assert_runs_equal(b: &InferRun, s: &InferRun, what: &str) {
    assert_eq!(b.total_cycles, s.total_cycles, "{what}: total cycles");
    assert_eq!(
        b.total_energy_uj.to_bits(),
        s.total_energy_uj.to_bits(),
        "{what}: total energy bits"
    );
    assert_eq!(b.relu_cycles, s.relu_cycles, "{what}: relu cycles");
    assert_eq!(b.layers.len(), s.layers.len(), "{what}: layer count");
    for (i, (bl, sl)) in b.layers.iter().zip(s.layers.iter()).enumerate() {
        assert_eq!(bl.cycles, sl.cycles, "{what}: layer {i} cycles");
        assert_eq!(bl.conv_cycles, sl.conv_cycles, "{what}: layer {i} conv cycles");
        assert_eq!(bl.host_cycles, sl.host_cycles, "{what}: layer {i} host cycles");
        assert_eq!(
            bl.energy_uj.to_bits(),
            sl.energy_uj.to_bits(),
            "{what}: layer {i} energy bits"
        );
        assert_eq!(bl.launches, sl.launches, "{what}: layer {i} launches");
        assert_eq!(bl.mapping, sl.mapping, "{what}: layer {i} mapping");
    }
}

/// Run `inputs` through `run_batch` and through B sequential scalar
/// runs, and assert the batched path is bit-identical per lane.
fn check_batch_vs_scalar(compiled: &CompiledNet, inputs: &[TensorChw], what: &str) {
    let mut bctx = compiled.new_batch_ctx(inputs.len());
    let brun = compiled.run_batch(&mut bctx, inputs).unwrap();
    assert_eq!(bctx.outputs().len(), inputs.len(), "{what}: served lanes");
    let mut sctx = compiled.new_ctx();
    for (l, input) in inputs.iter().enumerate() {
        let srun = compiled.run(&mut sctx, input).unwrap();
        assert_eq!(
            bctx.outputs()[l].data,
            sctx.output().data,
            "{what}: lane {l} output"
        );
        assert_runs_equal(&brun, &srun, &format!("{what} lane {l}"));
    }
}

/// Property: across the stride × pad × groups lowering grid, a batched
/// run over B distinct inputs is bit-identical to B sequential scalar
/// runs — outputs, modeled cycles, modeled energy — including the B=1
/// degenerate batch.
#[test]
fn prop_batched_matches_scalar_across_grid() {
    let engine = engine();
    let mut case = 0u64;
    for &stride in &[1usize, 2] {
        for &pad in &[0usize, 1] {
            for &groups in &[1usize, 2, 4] {
                case += 1;
                let net = grid_net(stride, pad, groups, 31 + case);
                let compiled = engine.compile(&net).unwrap();
                for nb in [1usize, 3] {
                    let inputs: Vec<_> = (0..nb as u64)
                        .map(|l| net.random_input(10, 5 + case * 100 + l))
                        .collect();
                    check_batch_vs_scalar(
                        &compiled,
                        &inputs,
                        &format!("{} B={nb}", net.name),
                    );
                }
            }
        }
    }
    assert_eq!(case, 12);
}

/// The mobilenet-mini preset (depthwise/pointwise chains, pools,
/// strides — the serving benchmark's network) batches bit-exactly.
#[test]
fn preset_batches_bit_exactly() {
    let engine = engine();
    let net = nn::build_preset("mobilenet-mini", 7).unwrap();
    let compiled = engine.compile(&net).unwrap();
    let inputs: Vec<_> = (0..2u64).map(|l| net.random_input(8, 7 ^ (l << 8))).collect();
    check_batch_vs_scalar(&compiled, &inputs, "mobilenet-mini B=2");
}

/// A ragged final chunk — fewer inputs than the context's capacity —
/// runs through the same capacity-strided layout, serves only the
/// presented lanes, and stays bit-exact; the context then accepts a
/// full chunk again.
#[test]
fn ragged_final_chunk_is_exact() {
    let engine = engine();
    let net = grid_net(2, 1, 2, 9);
    let compiled = engine.compile(&net).unwrap();
    let mut bctx = compiled.new_batch_ctx(4);
    let mut sctx = compiled.new_ctx();

    for nb in [4usize, 3, 1, 4] {
        let inputs: Vec<_> =
            (0..nb as u64).map(|l| net.random_input(10, 1000 * nb as u64 + l)).collect();
        let brun = compiled.run_batch(&mut bctx, &inputs).unwrap();
        assert_eq!(bctx.outputs().len(), nb, "served lanes after a chunk of {nb}");
        for (l, input) in inputs.iter().enumerate() {
            let srun = compiled.run(&mut sctx, input).unwrap();
            assert_eq!(bctx.outputs()[l].data, sctx.output().data, "chunk {nb} lane {l}");
            assert_runs_equal(&brun, &srun, &format!("chunk {nb} lane {l}"));
        }
    }
}

/// The golden debug mode verifies every lane of every layer and
/// reports exactness, like the scalar `run_verified`.
#[test]
fn batched_verified_runs_are_golden_exact() {
    let engine = engine();
    let net = grid_net(1, 1, 1, 17);
    let compiled = engine.compile(&net).unwrap();
    let mut bctx = compiled.new_batch_ctx(3);
    let inputs: Vec<_> = (0..3u64).map(|l| net.random_input(10, 40 + l)).collect();
    let run = compiled.run_batch_verified(&mut bctx, &inputs).unwrap();
    assert_eq!(run.exact, Some(true), "every lane of every layer must be golden-exact");
    for lr in &run.layers {
        assert_eq!(lr.exact, Some(true));
    }
    // The unverified path reports no exactness claim.
    let run = compiled.run_batch(&mut bctx, &inputs).unwrap();
    assert_eq!(run.exact, None);
}

/// Argument validation: empty batches, over-capacity batches and
/// wrong-shaped lane inputs are rejected with actionable messages.
#[test]
fn run_batch_validates_inputs() {
    let engine = engine();
    let net = grid_net(1, 0, 1, 3);
    let compiled = engine.compile(&net).unwrap();
    let mut bctx = compiled.new_batch_ctx(2);

    let err = format!("{:#}", compiled.run_batch(&mut bctx, &[]).unwrap_err());
    assert!(err.contains("capacity 2"), "{err}");
    assert!(bctx.outputs().is_empty(), "a failed run serves no lanes");

    let three: Vec<_> = (0..3u64).map(|l| net.random_input(10, l)).collect();
    let err = format!("{:#}", compiled.run_batch(&mut bctx, &three).unwrap_err());
    assert!(err.contains("3 inputs") && err.contains("capacity 2"), "{err}");

    let bad = nn::build_preset("mobilenet-mini", 1).unwrap().random_input(8, 1);
    let good = net.random_input(10, 9);
    let err =
        format!("{:#}", compiled.run_batch(&mut bctx, &[good, bad]).unwrap_err());
    assert!(err.contains("batch lane 1"), "{err}");
}
