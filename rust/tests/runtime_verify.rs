//! The cross-language gate: every AOT artifact (JAX/Pallas → HLO text)
//! must agree bit-exactly with the Rust golden model AND the CGRA
//! simulator. Requires `make artifacts` (the Makefile test target runs
//! it first); skips with a loud message when artifacts are absent so
//! `cargo test` alone stays usable.

use openedge_cgra::runtime::{verify_all, Manifest, Runtime};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn artifacts_verify_bit_exactly() {
    if !cfg!(feature = "pjrt") {
        eprintln!("SKIP runtime_verify: built without the `pjrt` feature (stub runtime)");
        return;
    }
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!(
            "SKIP runtime_verify: {} missing — run `make artifacts` first",
            dir.join("manifest.json").display()
        );
        return;
    }
    let summary = verify_all(&dir).expect("verification run");
    println!("{summary}");
    assert!(summary.all_passed(), "artifact verification failed:\n{summary}");
    // The manifest must exercise both Layer-1 kernels and the CNN.
    assert!(summary.rows.iter().any(|r| r.name.contains("direct")));
    assert!(summary.rows.iter().any(|r| r.name.contains("im2col")));
    assert!(summary.rows.iter().any(|r| r.name.starts_with("cnn")));
}

#[test]
fn runtime_reports_platform() {
    if !cfg!(feature = "pjrt") {
        eprintln!("SKIP runtime platform test: built without the `pjrt` feature");
        return;
    }
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP runtime platform test: artifacts missing");
        return;
    }
    let rt = Runtime::cpu().expect("PJRT client");
    assert!(rt.platform().to_lowercase().contains("cpu"));
}

#[test]
fn manifest_shapes_match_files() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP manifest test: artifacts missing");
        return;
    }
    let m = Manifest::load(&dir).expect("manifest");
    assert!(!m.artifacts.is_empty());
    for a in &m.artifacts {
        assert!(dir.join(&a.file).exists(), "artifact file {} missing", a.file);
    }
}
