//! Integration and property tests of the `nn` layer-graph subsystem:
//! the generalized golden model against an independent naive reference,
//! depthwise ≡ grouped-conv identities (golden *and* CGRA kernel),
//! pooling identities, the stride-1/pad-0 regression (bit-identical
//! results, same sweep-cache keys), and end-to-end preset execution.

use openedge_cgra::cgra::{Cgra, CgraConfig};
use openedge_cgra::conv::{
    conv2d, conv2d_general, depthwise2d, random_depthwise_weights, random_input, ConvShape,
    GenConvShape, TensorChw, Weights,
};
use openedge_cgra::engine::{ConvRequest, EngineBuilder};
use openedge_cgra::kernels::{dw, Mapping};
use openedge_cgra::nn::{self, Layer, Net};
use openedge_cgra::planner::PlanObjective;
use openedge_cgra::prop::Rng;

/// An independent naive reference: materialize the zero-padded input
/// explicitly, then run the quadruple loop over (k, y, x) × (c, fy, fx)
/// with explicit group arithmetic. Deliberately structured differently
/// from `conv2d_general` (which bounds-checks instead of padding) so
/// the two implementations cannot share a bug.
fn naive_reference(shape: &GenConvShape, input: &TensorChw, weights: &Weights) -> Vec<i32> {
    let p = shape.pad;
    let (ph, pw) = (shape.ih + 2 * p, shape.iw + 2 * p);
    let mut padded = vec![0i32; shape.c * ph * pw];
    for c in 0..shape.c {
        for y in 0..shape.ih {
            for x in 0..shape.iw {
                padded[(c * ph + y + p) * pw + x + p] = input.at(c, y, x);
            }
        }
    }
    let (ox, oy) = (shape.ox(), shape.oy());
    let (cg, kg) = (shape.c_per_group(), shape.k_per_group());
    let mut out = vec![0i32; shape.k * ox * oy];
    for k in 0..shape.k {
        let g = k / kg;
        for y in 0..ox {
            for x in 0..oy {
                let mut acc = 0i32;
                for c in 0..cg {
                    for fy in 0..shape.fx {
                        for fx in 0..shape.fy {
                            let iv = padded[((g * cg + c) * ph + y * shape.stride + fy) * pw
                                + x * shape.stride
                                + fx];
                            let wv = weights.at(k, c, fy, fx);
                            acc = acc.wrapping_add(iv.wrapping_mul(wv));
                        }
                    }
                }
                out[(k * ox + y) * oy + x] = acc;
            }
        }
    }
    out
}

/// Property: the generalized golden model agrees with the naive
/// reference over a grid of strided / padded / grouped shapes.
#[test]
fn prop_general_golden_matches_naive_reference() {
    let mut rng = Rng::new(0xbead);
    let mut cases = 0;
    for &(c, k, groups) in &[(1usize, 1usize, 1usize), (2, 4, 1), (4, 4, 2), (6, 6, 6)] {
        for &stride in &[1usize, 2, 3] {
            for &pad in &[0usize, 1, 2] {
                for &(fx, fy) in &[(3usize, 3usize), (1, 1)] {
                    let (ih, iw) = (7, 8);
                    let Ok(shape) = GenConvShape::new(c, k, ih, iw, fx, fy, stride, pad, groups)
                    else {
                        continue;
                    };
                    let input = TensorChw::random(c, ih, iw, 60, &mut rng);
                    let weights =
                        Weights::random(k, shape.c_per_group(), fx, fy, 10, &mut rng);
                    let golden = conv2d_general(&shape, &input, &weights);
                    assert_eq!(
                        golden.data,
                        naive_reference(&shape, &input, &weights),
                        "mismatch on {shape}"
                    );
                    cases += 1;
                }
            }
        }
    }
    assert!(cases >= 50, "property grid too small: {cases} cases");
}

/// Regression: stride-1 / pad-0 / groups-1 results are bit-identical to
/// the pre-generalization golden model, and the lowered shape is the
/// exact `ConvShape` — so seeded submissions share the same sweep-cache
/// entry as before the nn subsystem existed.
#[test]
fn stride1_regression_bit_identical_and_same_cache_keys() {
    // Bit-identical outputs.
    let basic = ConvShape::new3x3(4, 5, 6, 7);
    let gen = GenConvShape::from_basic(&basic);
    let mut rng = Rng::new(77);
    let input = random_input(&basic, 50, &mut rng);
    let weights = openedge_cgra::conv::random_weights(&basic, 9, &mut rng);
    assert_eq!(conv2d(&basic, &input, &weights).data, conv2d_general(&gen, &input, &weights).data);

    // Same cache keys: a seeded submission keyed by the *lowered* shape
    // hits the entry created by the plain pre-nn shape.
    let engine = EngineBuilder::new().workers(1).private_cache().build().unwrap();
    let first = engine.submit(&ConvRequest::seeded(basic, Mapping::Wp, 21)).unwrap();
    assert!(!first.cache_hit);
    let lowered = gen.to_basic().expect("stride-1 layer lowers to the basic shape");
    let second = engine.submit(&ConvRequest::seeded(lowered, Mapping::Wp, 21)).unwrap();
    assert!(second.cache_hit, "lowered shape must hit the pre-nn cache entry");
    assert_eq!(engine.cache_stats().entries, 1);
    assert_eq!(first.output.data, second.output.data);
}

/// Depthwise ≡ grouped conv with groups = C, on the golden model AND on
/// the simulated CGRA kernel.
#[test]
fn depthwise_kernel_equals_grouped_conv_golden() {
    let shape = ConvShape::new3x3(6, 6, 5, 5);
    let gen = GenConvShape { groups: 6, ..GenConvShape::from_basic(&shape) };
    let mut rng = Rng::new(101);
    let input = random_input(&shape, 40, &mut rng);
    let w = random_depthwise_weights(&shape, 9, &mut rng);
    let via_groups = conv2d_general(&gen, &input, &w);
    let via_dw_golden = depthwise2d(&shape, &input, &w);
    assert_eq!(via_groups.data, via_dw_golden.data);
    let cgra = Cgra::new(CgraConfig::default()).unwrap();
    let kernel = dw::run(&cgra, &shape, &input, &w).unwrap();
    assert_eq!(kernel.output.data, via_groups.data, "Dw-WP must match the grouped golden");
}

/// Pooling identities on random data: size-1 pooling is the identity,
/// max dominates the truncated mean, and ReLU commutes with max pool.
#[test]
fn pooling_identities() {
    use openedge_cgra::nn::lower::{avgpool2d, maxpool2d};
    let mut rng = Rng::new(55);
    let x = TensorChw::random(3, 6, 6, 100, &mut rng);
    assert_eq!(maxpool2d(&x, 1, 1).0, x);
    assert_eq!(avgpool2d(&x, 1, 1).0, x);
    let (mx, _) = maxpool2d(&x, 2, 2);
    let (av, _) = avgpool2d(&x, 2, 2);
    for (a, b) in mx.data.iter().zip(av.data.iter()) {
        assert!(a >= b, "max {a} < avg {b}");
    }
    // relu(maxpool(x)) == maxpool(relu(x)).
    let mut rx = x.clone();
    for v in rx.data.iter_mut() {
        *v = (*v).max(0);
    }
    let (mrx, _) = maxpool2d(&rx, 2, 2);
    let mut rmx = mx.clone();
    for v in rmx.data.iter_mut() {
        *v = (*v).max(0);
    }
    assert_eq!(mrx, rmx);
}

/// Acceptance: `mobilenet-mini` runs every layer on the simulated CGRA,
/// per-layer outputs match the generalized golden model exactly, and
/// the planner-chosen mappings cover the depthwise kernel.
#[test]
fn mobilenet_mini_runs_end_to_end_exactly() {
    let engine = EngineBuilder::new().workers(2).private_cache().build().unwrap();
    let net = nn::build_preset("mobilenet-mini", 7).unwrap();
    let input = net.random_input(8, 7);
    let report = nn::run_network(&engine, &net, &input).unwrap();
    assert!(report.exact, "every layer must match the generalized golden model");
    assert!(report.layers.iter().all(|l| l.exact));
    // The depthwise layers ran on the Dw-WP kernel.
    let dw_layers: Vec<_> =
        report.layers.iter().filter(|l| l.kind == "depthwise").collect();
    assert_eq!(dw_layers.len(), 2);
    assert!(dw_layers.iter().all(|l| l.mapping == Some(Mapping::DwWp)));
    // Dense/pointwise layers got a planner-chosen concrete mapping.
    for l in report.layers.iter().filter(|l| l.kind != "maxpool" && l.kind != "avgpool") {
        assert!(l.mapping.is_some(), "layer {} has no mapping", l.index);
        assert!(l.launches > 0);
    }
    // The pool layer is host-only.
    assert!(report.layers.iter().any(|l| l.kind == "avgpool" && l.mapping.is_none()));
    assert_eq!((report.output.c, report.output.h, report.output.w), (10, 4, 4));

    // Plan-only agrees with the execution within the planner bound.
    let plan = nn::plan_network(engine.planner(), &net, PlanObjective::Latency).unwrap();
    let (p, s) = (plan.total_cycles as f64, report.total_cycles as f64);
    assert!(((p - s) / s).abs() <= 0.05, "planned {p} vs executed {s}");
}

/// The vgg-mini preset (padded convs + maxpools + a strided conv) is
/// exact too, and deterministic in the seed.
#[test]
fn vgg_mini_exact_and_deterministic() {
    let engine = EngineBuilder::new().workers(2).private_cache().build().unwrap();
    let net = nn::build_preset("vgg-mini", 3).unwrap();
    let input = net.random_input(8, 3);
    let a = nn::run_network(&engine, &net, &input).unwrap();
    let b = nn::run_network(&engine, &net, &input).unwrap();
    assert!(a.exact);
    assert_eq!(a.output.data, b.output.data);
    assert_eq!(a.total_cycles, b.total_cycles);
}

/// A single-layer paper-baseline net reports the same conv cycles as a
/// direct engine submission of `ConvShape::baseline()` — the lowering
/// adds zero overhead on the fast path.
#[test]
fn paper_baseline_preset_is_the_untouched_fast_path() {
    let engine = EngineBuilder::new().workers(1).private_cache().build().unwrap();
    let net = nn::build_preset("paper-baseline", 9).unwrap();
    let input = net.random_input(8, 9);
    let report = nn::run_network(&engine, &net, &input).unwrap();
    assert!(report.exact);
    let l = &report.layers[0];
    assert_eq!(l.host_cycles, 0, "no pad/decimate/relu glue on the baseline layer");
    assert_eq!(l.cycles, l.conv_cycles);
    // Same shape, same data path: a direct submission of the baseline
    // shape with the same mapping reports identical latency.
    let direct = engine
        .submit(&ConvRequest::with_data(
            ConvShape::baseline(),
            l.mapping.unwrap(),
            input.clone(),
            match &net.layers[0] {
                Layer::Conv { weights, .. } => weights.clone(),
                _ => unreachable!(),
            },
        ))
        .unwrap();
    assert_eq!(direct.report.latency_cycles, l.conv_cycles);
}

/// Graph validation rejects broken chains with the failing layer named,
/// and unknown presets list the available ones.
#[test]
fn validation_and_preset_errors_are_actionable() {
    let mut rng = Rng::new(2);
    let bad = Net {
        name: "broken".into(),
        input_dims: (3, 8, 8),
        layers: vec![
            Layer::conv(GenConvShape::new(3, 4, 8, 8, 3, 3, 1, 0, 1).unwrap(), true, 4, &mut rng)
                .unwrap(),
            // Expects 6 channels but gets 4.
            Layer::pointwise(6, 8, 6, 6, false, 4, &mut rng).unwrap(),
        ],
    };
    let err = format!("{:#}", bad.validate().unwrap_err());
    assert!(err.contains("layer 1") && err.contains("pointwise"), "{err}");
    let err = format!("{:#}", nn::build_preset("nope", 1).unwrap_err());
    assert!(err.contains("mobilenet-mini") && err.contains("vgg-mini"), "{err}");
}
