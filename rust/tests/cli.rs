//! CLI argument-validation behavior, driven against the real `cgra`
//! binary (`CARGO_BIN_EXE_cgra`): bad invocations must exit non-zero
//! with an actionable message instead of panicking or dividing by
//! zero downstream.

use std::process::Command;

fn cgra(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_cgra"))
        .args(args)
        .output()
        .expect("spawning the cgra binary")
}

/// `cgra serve --iters 0` used to reach the amortization divide; it
/// must be rejected up front with a usage error naming the option.
#[test]
fn serve_rejects_zero_iters() {
    let out = cgra(&["serve", "--iters", "0"]);
    assert!(!out.status.success(), "--iters 0 must exit non-zero");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--iters"), "the error must name the option: {stderr}");
}

#[test]
fn serve_rejects_zero_batch() {
    let out = cgra(&["serve", "--iters", "1", "--batch", "0"]);
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--batch"), "{stderr}");
}

/// The help text advertises every subcommand, including the daemon.
#[test]
fn help_lists_daemon() {
    let out = cgra(&["help"]);
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("daemon"), "{stdout}");
}

/// Unknown daemon options and bad policy values fail during argument
/// parsing — before any socket is bound.
#[test]
fn daemon_validates_arguments() {
    let out = cgra(&["daemon", "--admission", "bogus"]);
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("admission"), "{stderr}");

    let out = cgra(&["daemon", "--no-such-flag", "1"]);
    assert!(!out.status.success());

    let out = cgra(&["frobnicate"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}
