//! The AOT artifact file format (DESIGN.md §13) under fire: a clean
//! round trip across the preset grid, then a hostile-input suite — a
//! corrupted or mismatched artifact must always fail with a distinct,
//! actionable error, never a panic and never a silently-wrong load.
//!
//! File layout exercised here (see `engine::artifact`):
//!
//! ```text
//! [ magic "CGRART01" | u32 manifest_len LE | JSON manifest | payload ]
//! ```

use std::path::PathBuf;

use openedge_cgra::energy::EnergyModel;
use openedge_cgra::engine::{CompiledNet, Engine, EngineBuilder};
use openedge_cgra::nn;

fn engine() -> Engine {
    EngineBuilder::new().workers(1).private_cache().build().unwrap()
}

/// A per-test scratch directory under the OS temp root.
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cgra-artifact-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Serialize a small compiled preset and return (engine, file bytes).
fn artifact_bytes(preset: &str) -> (Engine, Vec<u8>) {
    let engine = engine();
    let net = nn::build_preset(preset, 7).unwrap();
    let compiled = engine.compile_owned(net).unwrap();
    (engine, compiled.serialize())
}

/// Load `bytes` from a temp file and return the error it must produce.
fn load_err(engine: &Engine, tag: &str, bytes: &[u8]) -> String {
    let dir = scratch(tag);
    let path = dir.join("artifact.cgrart");
    std::fs::write(&path, bytes).unwrap();
    let err = CompiledNet::load(engine, &path)
        .err()
        .unwrap_or_else(|| panic!("corrupted artifact ({tag}) must be rejected"));
    std::fs::remove_dir_all(&dir).ok();
    format!("{err:#}")
}

/// The manifest region of a serialized artifact: (start, end) offsets.
fn manifest_span(bytes: &[u8]) -> (usize, usize) {
    let mlen = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize;
    (12, 12 + mlen)
}

/// Rebuild an artifact image around a patched manifest string.
fn with_manifest(bytes: &[u8], manifest: &str) -> Vec<u8> {
    let (start, end) = manifest_span(bytes);
    let mut out = Vec::with_capacity(bytes.len());
    out.extend_from_slice(&bytes[..8]);
    out.extend_from_slice(&(manifest.len() as u32).to_le_bytes());
    out.extend_from_slice(manifest.as_bytes());
    out.extend_from_slice(&bytes[end..]);
    assert!(start == 12, "header layout drifted");
    out
}

#[test]
fn round_trip_is_bit_identical_across_presets() {
    let engine = engine();
    let dir = scratch("roundtrip");
    for preset in ["mobilenet-mini", "vgg-mini", "paper-baseline"] {
        let net = nn::build_preset(preset, 7).unwrap();
        let compiled = engine.compile(&net).unwrap();
        let path = dir.join(format!("{preset}.cgrart"));
        let saved = compiled.save(&path).unwrap();
        assert_eq!(saved.net, preset, "artifact records the net name");
        assert_eq!(saved.net_fp, net.fingerprint());
        assert_eq!(saved.session_fp, engine.session_fingerprint());
        assert_eq!(
            saved.file_bytes,
            std::fs::metadata(&path).unwrap().len() as usize,
            "reported size matches the file"
        );

        let (loaded, info) = CompiledNet::load(&engine, &path).unwrap();
        assert_eq!(info, saved, "load reports the identity save recorded");

        // Replays are bit-identical: outputs, cycles and energy.
        let input = net.random_input(8, 11);
        let (mut ca, mut cb) = (compiled.new_ctx(), loaded.new_ctx());
        let ra = compiled.run_verified(&mut ca, &input).unwrap();
        let rb = loaded.run_verified(&mut cb, &input).unwrap();
        assert_eq!(ra.exact, Some(true));
        assert_eq!(rb.exact, Some(true), "{preset}: loaded artifact stays golden-exact");
        assert_eq!(ra.total_cycles, rb.total_cycles, "{preset}: cycles");
        assert_eq!(
            ra.total_energy_uj.to_bits(),
            rb.total_energy_uj.to_bits(),
            "{preset}: energy"
        );
        assert_eq!(ca.output().data, cb.output().data, "{preset}: outputs");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn truncated_files_are_rejected_at_every_cut() {
    let (engine, bytes) = artifact_bytes("mobilenet-mini");

    // Below the fixed header.
    let err = load_err(&engine, "trunc-header", &bytes[..7]);
    assert!(err.contains("too short"), "{err}");

    // Header intact, manifest cut.
    let (_, mend) = manifest_span(&bytes);
    let err = load_err(&engine, "trunc-manifest", &bytes[..mend - 3]);
    assert!(err.contains("manifest truncated"), "{err}");

    // Payload cut: the manifest's promised length catches it before
    // any payload byte is decoded.
    let err = load_err(&engine, "trunc-payload", &bytes[..bytes.len() - 5]);
    assert!(err.contains("truncated or carries trailing garbage"), "{err}");

    // Trailing garbage is the same class of mismatch.
    let mut padded = bytes.clone();
    padded.extend_from_slice(b"junk");
    let err = load_err(&engine, "trailing", &padded);
    assert!(err.contains("truncated or carries trailing garbage"), "{err}");
}

#[test]
fn flipped_payload_byte_fails_the_checksum() {
    let (engine, mut bytes) = artifact_bytes("mobilenet-mini");
    let last = bytes.len() - 1;
    bytes[last] ^= 0x40;
    let err = load_err(&engine, "checksum", &bytes);
    assert!(err.contains("checksum mismatch"), "{err}");
    assert!(err.contains("corrupted"), "{err}");
}

#[test]
fn wrong_magic_is_rejected_before_anything_is_parsed() {
    let (engine, mut bytes) = artifact_bytes("mobilenet-mini");
    bytes[..8].copy_from_slice(b"NOTCGRA!");
    let err = load_err(&engine, "magic", &bytes);
    assert!(err.contains("bad magic"), "{err}");
}

#[test]
fn unreadable_manifest_is_rejected() {
    let (engine, bytes) = artifact_bytes("mobilenet-mini");
    let (mstart, mend) = manifest_span(&bytes);
    let mut garbled = bytes.clone();
    for b in &mut garbled[mstart..mend] {
        *b = b'x';
    }
    let err = load_err(&engine, "manifest-garbage", &garbled);
    assert!(err.contains("manifest"), "{err}");
}

#[test]
fn format_version_bump_demands_a_recompile() {
    let (engine, bytes) = artifact_bytes("mobilenet-mini");
    let (mstart, mend) = manifest_span(&bytes);
    let manifest = std::str::from_utf8(&bytes[mstart..mend]).unwrap();
    assert!(manifest.contains("\"format_version\":1"), "layout drifted: {manifest}");
    let patched = manifest.replace("\"format_version\":1", "\"format_version\":99");
    let err = load_err(&engine, "format-version", &with_manifest(&bytes, &patched));
    assert!(err.contains("format version 99"), "{err}");
    assert!(err.contains("recompile"), "{err}");
}

#[test]
fn crate_version_mismatch_demands_a_recompile() {
    let (engine, bytes) = artifact_bytes("mobilenet-mini");
    let (mstart, mend) = manifest_span(&bytes);
    let manifest = std::str::from_utf8(&bytes[mstart..mend]).unwrap();
    let cur = format!("\"crate_version\":\"{}\"", env!("CARGO_PKG_VERSION"));
    assert!(manifest.contains(&cur), "layout drifted: {manifest}");
    let patched = manifest.replace(&cur, "\"crate_version\":\"0.0.1\"");
    let err = load_err(&engine, "crate-version", &with_manifest(&bytes, &patched));
    assert!(err.contains("crate version 0.0.1"), "{err}");
    assert!(err.contains("recompile"), "{err}");
}

#[test]
fn manifest_net_fingerprint_must_match_the_payload() {
    let (engine, bytes) = artifact_bytes("mobilenet-mini");
    let (mstart, mend) = manifest_span(&bytes);
    let manifest = std::str::from_utf8(&bytes[mstart..mend]).unwrap();
    // Patch the 16-hex net_fp to a different same-length value.
    let key = "\"net_fp\":\"";
    let at = manifest.find(key).unwrap() + key.len();
    let old = &manifest[at..at + 16];
    let new: String = old
        .chars()
        .map(|c| if c == 'f' { '0' } else { 'f' })
        .collect();
    let patched = manifest.replace(&format!("{key}{old}"), &format!("{key}{new}"));
    let err = load_err(&engine, "net-fp", &with_manifest(&bytes, &patched));
    assert!(err.contains("manifest and payload disagree"), "{err}");
}

#[test]
fn session_fingerprint_mismatch_names_both_sessions() {
    // Compile under the calibrated session, load under a session with a
    // doubled memory-access energy: the frozen charges would be wrong,
    // so the load must refuse and say why.
    let (_, bytes) = artifact_bytes("mobilenet-mini");
    let mut hot = EnergyModel::default();
    hot.e_mem_access_pj *= 2.0;
    let other = EngineBuilder::new().energy_model(hot).private_cache().build().unwrap();
    let err = load_err(&other, "session-fp", &bytes);
    assert!(err.contains("session fingerprint"), "{err}");
    assert!(err.contains("energy model"), "{err}");
}

#[test]
fn missing_file_error_names_the_path() {
    let engine = engine();
    let path = std::env::temp_dir().join("cgra-artifact-definitely-missing.cgrart");
    let err = format!("{:#}", CompiledNet::load(&engine, &path).unwrap_err());
    assert!(err.contains("cgra-artifact-definitely-missing"), "{err}");
}
