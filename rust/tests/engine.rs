//! Engine-level integration tests: batch ⇔ sequential equivalence on
//! randomized shapes, `Mapping::Auto` differentially tested against the
//! golden model and the hand-picked strategies, and cache-hit
//! semantics across repeat submissions.

use openedge_cgra::conv::{conv2d, random_input, random_weights, ConvShape};
use openedge_cgra::engine::{ConvRequest, Engine, EngineBuilder};
use openedge_cgra::kernels::Mapping;
use openedge_cgra::prop::{forall, usize_in, Gen, Rng};

fn private_engine(workers: usize) -> Engine {
    EngineBuilder::new().workers(workers).private_cache().build().unwrap()
}

fn shape_gen(max_ch: usize, max_sp: usize) -> Gen<ConvShape> {
    usize_in(1, max_ch)
        .pair(usize_in(1, max_ch))
        .pair(usize_in(1, max_sp).pair(usize_in(1, max_sp)))
        .map(|((c, k), (ox, oy))| ConvShape::new3x3(c, k, ox, oy))
}

/// `submit_batch` results are bit-identical to sequential `submit`
/// calls on randomized shapes — outputs, latency cycles and energy
/// bits — regardless of worker count.
#[test]
fn prop_batch_matches_sequential() {
    forall("submit_batch == sequential submit", 10, &shape_gen(5, 6), |s| {
        let mut rng = Rng::new(8800 + s.c as u64 + 7 * s.oy as u64);
        // Two shapes per round (the generated one + a sibling) so the
        // batch exercises inter-request ordering, across 3 mappings.
        let sibling = ConvShape::new3x3(s.k, s.c, s.oy, s.ox);
        let mut reqs = Vec::new();
        for &shape in &[*s, sibling] {
            for m in [Mapping::Wp, Mapping::OpDirect, Mapping::Cpu] {
                let input = random_input(&shape, 40, &mut rng);
                let weights = random_weights(&shape, 9, &mut rng);
                reqs.push(ConvRequest::with_data(shape, m, input, weights));
            }
        }
        // Independent engines with private caches: no cross-talk.
        let seq_engine = private_engine(1);
        let batch_engine = private_engine(4);
        let batch = batch_engine.submit_batch(&reqs);
        for (req, batched) in reqs.iter().zip(batch) {
            let a = seq_engine.submit(req).map_err(|e| format!("seq: {e:#}"))?;
            let b = batched.map_err(|e| format!("batch: {e:#}"))?;
            if a.output.data != b.output.data {
                return Err(format!("{}: outputs differ", req.shape));
            }
            if a.report.latency_cycles != b.report.latency_cycles {
                return Err(format!("{}: latency differs", req.shape));
            }
            if a.report.energy_uj.to_bits() != b.report.energy_uj.to_bits() {
                return Err(format!("{}: energy differs", req.shape));
            }
            if a.cache_hit || b.cache_hit {
                return Err("tensor requests must not hit any cache".into());
            }
        }
        Ok(())
    });
}

/// Seeded batches agree with seeded sequential submission even when the
/// cache serves part of the batch (golden-reconstructed outputs are
/// bit-exact vs simulated ones).
#[test]
fn seeded_batch_matches_sequential_through_cache() {
    let shapes: Vec<ConvShape> =
        (2..8).map(|i| ConvShape::new3x3(i, 9 - i, 4 + i % 3, 5)).collect();
    let reqs: Vec<ConvRequest> = shapes
        .iter()
        .map(|&s| ConvRequest::seeded(s, Mapping::Wp, 31 + s.c as u64))
        .collect();
    let fresh = private_engine(4);
    let first = fresh.submit_batch(&reqs);
    let second = fresh.submit_batch(&reqs);
    for ((a, b), req) in first.iter().zip(second.iter()).zip(reqs.iter()) {
        let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
        assert!(!a.cache_hit, "{}: first pass must simulate", req.shape);
        assert!(b.cache_hit, "{}: second pass must hit", req.shape);
        assert_eq!(a.output.data, b.output.data, "{}", req.shape);
        assert_eq!(a.report.latency_cycles, b.report.latency_cycles);
    }
}

/// `Mapping::Auto` on the Fig. 4 baseline layer: bit-exact against the
/// golden model and never worse than the worst hand-picked mapping —
/// in fact it must match the best (WP on the paper's layer).
#[test]
fn auto_never_loses_on_fig4_layer() {
    let engine = private_engine(4);
    let shape = ConvShape::baseline();
    let mut rng = Rng::new(4);
    let input = random_input(&shape, 30, &mut rng);
    let weights = random_weights(&shape, 9, &mut rng);
    let golden = conv2d(&shape, &input, &weights);

    let auto = engine
        .submit(&ConvRequest::with_data(shape, Mapping::Auto, input.clone(), weights.clone()))
        .unwrap();
    assert_eq!(auto.output.data, golden.data, "Auto output must match the golden model");
    let decision = auto.auto.expect("decision recorded");
    assert_eq!(decision.mapping, auto.mapping);

    let mut hand_picked = Vec::new();
    for m in Mapping::ALL {
        let res = engine
            .submit(&ConvRequest::with_data(shape, m, input.clone(), weights.clone()))
            .unwrap();
        assert_eq!(res.output.data, golden.data, "{m}");
        hand_picked.push(res.report);
    }
    let worst = hand_picked.iter().map(|r| r.latency_cycles).max().unwrap();
    let best = hand_picked.iter().map(|r| r.latency_cycles).min().unwrap();
    assert!(
        auto.report.latency_cycles < worst,
        "Auto ({}) must beat the worst hand-picked mapping ({worst})",
        auto.report.latency_cycles
    );
    assert_eq!(
        auto.report.latency_cycles, best,
        "on the paper's baseline layer Auto must match the best mapping"
    );
    assert_eq!(auto.mapping, Mapping::Wp, "the paper's winner");
}

/// Cache-hit flags are set on repeat submission and the underlying
/// cache counters line up.
#[test]
fn cache_hit_flags_on_repeat_submission() {
    let engine = private_engine(2);
    let req = ConvRequest::seeded(ConvShape::new3x3(4, 3, 5, 5), Mapping::Auto, 77);
    let first = engine.submit(&req).unwrap();
    assert!(!first.cache_hit);
    let second = engine.submit(&req).unwrap();
    assert!(second.cache_hit);
    let third = engine.submit(&req).unwrap();
    assert!(third.cache_hit);
    assert_eq!(first.output.data, second.output.data);
    assert_eq!(second.output.data, third.output.data);
    let s = engine.cache_stats();
    assert_eq!((s.hits, s.misses, s.entries), (2, 1, 1));
    // The recorded auto decision survives cache hits.
    assert_eq!(second.auto.unwrap().mapping, first.auto.unwrap().mapping);
}

/// Cached and freshly simulated results report identical host-ReLU
/// accounting: the `RELU_CYCLES_PER_ELEM` path in `engine::submit` runs
/// after cache resolution, so a hit's golden-reconstructed output must
/// be clamped and charged exactly like the simulated one.
#[test]
fn cached_and_fresh_results_share_relu_accounting() {
    let engine = private_engine(2);
    let shape = ConvShape::new3x3(3, 2, 4, 4);
    let req = ConvRequest::seeded(shape, Mapping::Wp, 5).relu(true);
    let fresh = engine.submit(&req).unwrap();
    let cached = engine.submit(&req).unwrap();
    assert!(!fresh.cache_hit && cached.cache_hit, "second submission must hit");
    assert_eq!(fresh.relu_cycles, cached.relu_cycles);
    assert_eq!(fresh.relu_cycles, 3 * shape.output_elems() as u64);
    assert_eq!(fresh.relu_energy_uj.to_bits(), cached.relu_energy_uj.to_bits());
    assert_eq!(fresh.total_cycles(), cached.total_cycles());
    assert_eq!(fresh.total_energy_uj().to_bits(), cached.total_energy_uj().to_bits());
    assert_eq!(fresh.output.data, cached.output.data);
    assert!(fresh.output.data.iter().all(|&v| v >= 0), "ReLU applied on both paths");
    // The convolution row itself excludes the ReLU on both paths.
    assert_eq!(fresh.report.latency_cycles, cached.report.latency_cycles);
}

/// `CacheStats` counters stay coherent under concurrent `submit_batch`
/// traffic with duplicate keys and a cached skip: every lookup is
/// counted, entries dedup, and the second pass is served entirely from
/// the cache (including the memory-bound skip).
#[test]
fn cache_stats_under_concurrent_batches() {
    let engine = private_engine(8);
    let shapes: Vec<ConvShape> = (1..=6).map(|i| ConvShape::new3x3(i, 2, 3, 3)).collect();
    let mut reqs: Vec<ConvRequest> = Vec::new();
    for _ in 0..3 {
        reqs.extend(shapes.iter().map(|&s| ConvRequest::seeded(s, Mapping::Wp, 99)));
    }
    // One oversized request: the error is cached as a skip entry.
    reqs.push(ConvRequest::seeded(ConvShape::new3x3(16, 16, 64, 64), Mapping::Wp, 99));
    let first = engine.submit_batch(&reqs);
    assert_eq!(first.iter().filter(|r| r.is_err()).count(), 1);
    let s = engine.cache_stats();
    // 6 unique points + 1 skip resident; duplicate keys racing through
    // the pool may each miss (check-then-insert), but inserts dedup.
    assert_eq!(s.entries, 7);
    assert_eq!(s.hits + s.misses, reqs.len() as u64, "every lookup is counted");
    assert!(s.misses >= 7, "at least one miss per unique key, got {}", s.misses);
    assert_eq!(s.evictions, 0);
    // Second identical batch: all 19 lookups hit, nothing new resident.
    let second = engine.submit_batch(&reqs);
    assert_eq!(second.iter().filter(|r| r.is_err()).count(), 1);
    let s2 = engine.cache_stats();
    assert_eq!(s2.entries, 7);
    assert_eq!(s2.hits, s.hits + reqs.len() as u64);
    assert_eq!(s2.misses, s.misses);
    // Hit results are bit-identical to the originals.
    for (a, b) in first.iter().zip(second.iter()) {
        match (a, b) {
            (Ok(x), Ok(y)) => {
                assert!(y.cache_hit);
                assert_eq!(x.report.latency_cycles, y.report.latency_cycles);
            }
            (Err(x), Err(y)) => assert_eq!(format!("{x:#}"), format!("{y:#}")),
            _ => panic!("outcome flipped between passes"),
        }
    }
}

/// Engines with different configs never share cache entries even when
/// they share one cache (the config fingerprint is part of the key).
#[test]
fn different_configs_do_not_cross_hit() {
    use openedge_cgra::cgra::CgraConfig;
    // Both engines on the default (process-global) cache: isolation
    // must come from the fingerprint in the key, not separate caches.
    // The seed/shape pair is unique to this test.
    let a = EngineBuilder::new().workers(1).build().unwrap();
    let req = ConvRequest::seeded(ConvShape::new3x3(3, 3, 4, 4), Mapping::Wp, 0xC0FF_EE01);
    assert!(!a.submit(&req).unwrap().cache_hit);
    assert!(a.submit(&req).unwrap().cache_hit, "same engine+config must hit");
    // Same request on an engine with an ablated config sharing the
    // global cache: must simulate, not hit (different fingerprint)...
    let slow = EngineBuilder::new()
        .config(CgraConfig { mem_latency: 12, ..CgraConfig::default() })
        .workers(1)
        .build()
        .unwrap();
    let res = slow.submit(&req).unwrap();
    assert!(!res.cache_hit, "ablated config must not be served default-config metrics");
    // ...and the ablated timing actually differs.
    let base = a.submit(&req).unwrap();
    assert!(res.report.latency_cycles > base.report.latency_cycles);
}

/// Cached rows embed evaluated energy numbers, so the energy model is
/// part of the key too: a session with a different model must simulate
/// rather than be served another session's rows.
#[test]
fn different_energy_models_do_not_cross_hit() {
    use openedge_cgra::energy::EnergyModel;
    let a = EngineBuilder::new().workers(1).build().unwrap();
    let req = ConvRequest::seeded(ConvShape::new3x3(3, 4, 4, 4), Mapping::Wp, 0xC0FF_EE02);
    let base = a.submit(&req).unwrap();
    assert!(!base.cache_hit);

    let mut hot = EnergyModel::default();
    hot.e_mem_access_pj *= 4.0;
    let b = EngineBuilder::new().energy_model(hot).workers(1).build().unwrap();
    let res = b.submit(&req).unwrap();
    assert!(!res.cache_hit, "a different energy model must not reuse cached rows");
    // Same simulation, different accounting.
    assert_eq!(res.report.latency_cycles, base.report.latency_cycles);
    assert!(res.report.energy_uj > base.report.energy_uj);
    assert_eq!(res.output.data, base.output.data);
}
