//! Property-based tests over the mapping kernels and the simulator
//! (hand-rolled `prop` framework — seeds replay via `PROP_SEED`).

use openedge_cgra::cgra::{Cgra, CgraConfig};
use openedge_cgra::conv::{conv2d, random_input, random_weights, ConvShape};
use openedge_cgra::engine::{ConvRequest, EngineBuilder};
use openedge_cgra::kernels::{op_im2col, wp, Mapping};
use openedge_cgra::prop::{choose, forall, usize_in, Gen, Rng};

fn shape_gen(max_ch: usize, max_sp: usize) -> Gen<ConvShape> {
    usize_in(1, max_ch)
        .pair(usize_in(1, max_ch))
        .pair(usize_in(1, max_sp).pair(usize_in(1, max_sp)))
        .map(|((c, k), (ox, oy))| ConvShape::new3x3(c, k, ox, oy))
}

fn check(mapping: Mapping, shape: &ConvShape, seed: u64) -> Result<(), String> {
    let mut rng = Rng::new(seed);
    let input = random_input(shape, 60, &mut rng);
    let weights = random_weights(shape, 12, &mut rng);
    let golden = conv2d(shape, &input, &weights);
    let engine = EngineBuilder::new().build().map_err(|e| e.to_string())?;
    let res = engine
        .submit(&ConvRequest::with_data(*shape, mapping, input, weights))
        .map_err(|e| format!("{e:#}"))?;
    if res.output.data != golden.data {
        let i = res.output.data.iter().zip(&golden.data).position(|(a, b)| a != b).unwrap();
        return Err(format!(
            "{mapping} mismatch on {shape} at flat index {i}: {} != {}",
            res.output.data[i], golden.data[i]
        ));
    }
    Ok(())
}

/// Every CGRA mapping is bit-exact against the golden convolution on
/// arbitrary small shapes (including non-multiples of 16).
#[test]
fn prop_wp_exact() {
    forall("WP == golden", 30, &shape_gen(6, 9), |s| check(Mapping::Wp, s, 1000 + s.c as u64));
}

#[test]
fn prop_op_im2col_exact() {
    forall("Im2col-OP == golden", 25, &shape_gen(6, 8), |s| {
        check(Mapping::OpIm2col, s, 2000 + s.k as u64)
    });
}

#[test]
fn prop_op_direct_exact() {
    forall("Conv-OP == golden", 25, &shape_gen(6, 8), |s| {
        check(Mapping::OpDirect, s, 3000 + s.oy as u64)
    });
}

#[test]
fn prop_ip_exact() {
    forall("Im2col-IP == golden", 20, &shape_gen(6, 6), |s| {
        check(Mapping::Ip, s, 4000 + s.ox as u64)
    });
}

/// Imbalanced channel counts around the 16-lane tile boundary.
#[test]
fn prop_tile_boundaries_exact() {
    let g = choose(vec![15usize, 16, 17, 31, 32, 33])
        .pair(choose(vec![Mapping::OpIm2col, Mapping::OpDirect, Mapping::Ip]));
    forall("tile-boundary dims exact", 12, &g, |(dim, mapping)| {
        let shape = match mapping {
            Mapping::Ip => ConvShape::new3x3(*dim, 3, 3, 3),
            _ => ConvShape::new3x3(2, *dim, 3, 3),
        };
        check(*mapping, &shape, 5000 + *dim as u64)
    });
}

/// Wrapping arithmetic: huge magnitudes overflow identically in the
/// simulator and the golden model.
#[test]
fn prop_wrapping_semantics() {
    forall("wrapping exactness", 8, &shape_gen(3, 4), |s| {
        let mut rng = Rng::new(77);
        let mut input = random_input(s, 1, &mut rng);
        let mut weights = random_weights(s, 1, &mut rng);
        for v in input.data.iter_mut() {
            *v = v.wrapping_mul(0x4000_0000);
        }
        for v in weights.data.iter_mut() {
            *v = v.wrapping_mul(0x0010_0000).wrapping_add(7);
        }
        let engine = EngineBuilder::new().build().map_err(|e| e.to_string())?;
        let golden = conv2d(s, &input, &weights);
        let res = engine
            .submit(&ConvRequest::with_data(*s, Mapping::Wp, input, weights))
            .map_err(|e| format!("{e:#}"))?;
        if res.output.data == golden.data {
            Ok(())
        } else {
            Err("wrapping mismatch".into())
        }
    });
}

/// Timing-model invariants: cycles ≥ steps; contention ≤ cycles; the
/// functional config (no contention) never exceeds the default config's
/// cycle count.
#[test]
fn prop_timing_invariants() {
    forall("timing invariants", 12, &shape_gen(4, 5), |s| {
        let mut rng = Rng::new(9);
        let input = random_input(s, 10, &mut rng);
        let weights = random_weights(s, 5, &mut rng);
        // Stats-level invariants live below the engine: drive the WP
        // generator directly (the engine's result is report-level).
        let fast = Cgra::new(CgraConfig::functional()).map_err(|e| e.to_string())?;
        let slow = Cgra::new(CgraConfig::default()).map_err(|e| e.to_string())?;
        let a = wp::run(&fast, s, &input, &weights).map_err(|e| format!("{e:#}"))?;
        let b = wp::run(&slow, s, &input, &weights).map_err(|e| format!("{e:#}"))?;
        if a.output.data != b.output.data {
            return Err("config must not change results".into());
        }
        let (sa, sb) = (&a.cgra_stats, &b.cgra_stats);
        if sb.cycles < sb.steps {
            return Err(format!("cycles {} < steps {}", sb.cycles, sb.steps));
        }
        if sb.contention_cycles > sb.cycles {
            return Err("contention exceeds cycles".into());
        }
        if sa.cycles > sb.cycles {
            return Err(format!(
                "functional config slower ({}) than contended ({})",
                sa.cycles, sb.cycles
            ));
        }
        // Identical instruction streams -> identical step counts.
        if sa.steps != sb.steps {
            return Err("step count must not depend on timing config".into());
        }
        Ok(())
    });
}

/// Same seed ⇒ identical stats (simulator determinism).
#[test]
fn prop_simulator_deterministic() {
    forall("determinism", 8, &shape_gen(4, 5), |s| {
        let a = run_stats(s)?;
        let b = run_stats(s)?;
        if a == b {
            Ok(())
        } else {
            Err("non-deterministic stats".into())
        }
    });

    fn run_stats(s: &ConvShape) -> Result<(u64, u64, u64), String> {
        let mut rng = Rng::new(13);
        let input = random_input(s, 10, &mut rng);
        let weights = random_weights(s, 5, &mut rng);
        let cgra = Cgra::new(CgraConfig::default()).map_err(|e| e.to_string())?;
        let out =
            op_im2col::run(&cgra, s, &input, &weights).map_err(|e| format!("{e:#}"))?;
        Ok((out.cgra_stats.cycles, out.cgra_stats.mem.loads, out.cgra_stats.mem.stores))
    }
}
