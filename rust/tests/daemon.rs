//! End-to-end daemon behavior: multi-tenant serving with registry
//! isolation, the degradation ladder, opportunistic batching, the wire
//! protocol (with and without a real socket), and stats accounting
//! against independent planner figures.

use std::sync::Arc;

use openedge_cgra::energy::EnergyModel;
use openedge_cgra::engine::EngineBuilder;
use openedge_cgra::nn::{plan_network, Net};
use openedge_cgra::planner::PlanObjective;
use openedge_cgra::server::{
    tcp, AdmissionPolicy, Daemon, InferRequest, NetSpec, Outcome, DAEMON_INPUT_MAG,
};

fn tiny_spec(seed: u64) -> NetSpec {
    NetSpec::Stack { depth: 1, c0: 2, k: 4, hw: 8, seed }
}

fn tiny_net(seed: u64) -> Net {
    Net::plain_stack(1, 2, 4, 8, seed).unwrap()
}

fn hot_model() -> EnergyModel {
    let mut m = EnergyModel::default();
    m.e_mem_access_pj *= 2.0;
    m.p_pe_active_mw *= 1.5;
    m
}

fn served(outcome: Outcome) -> openedge_cgra::server::Served {
    match outcome {
        Outcome::Served(s) => s,
        Outcome::Rejected(r) => panic!("unexpected rejection: {}", r.detail),
    }
}

/// Two tenants with different energy models, interleaved traffic:
/// outputs bit-identical to direct `CompiledNet::run`, energies
/// diverge, the registry never cross-hits, and per-tenant priced µJ
/// matches an independent `plan_network` twin.
#[test]
fn two_tenants_interleaved_with_isolated_pricing() {
    let daemon = Daemon::builder().workers(2).batch(4).build();
    daemon.register_tenant("cold", EnergyModel::default()).unwrap();
    daemon.register_tenant("hot", hot_model()).unwrap();

    let net_seed = 11;
    let mut outs = Vec::new();
    for round in 0..2u64 {
        for tenant in ["cold", "hot"] {
            let mut req = InferRequest::new(tenant, tiny_spec(net_seed));
            req.input_seed = round;
            req.collect_outputs = true;
            let s = served(daemon.submit(req).unwrap());
            assert_eq!(s.count, 1);
            assert_eq!(s.cache_hit, round > 0, "round 0 compiles, round 1 hits");
            assert!(s.degrade_steps.is_empty());
            outs.push((tenant, round, s));
        }
    }

    // Outputs must be bit-identical to a direct compile-and-run with
    // the same input recipe — per tenant model (functionally identical
    // across models too).
    let net = tiny_net(net_seed);
    let direct_engine = EngineBuilder::new().workers(1).build().unwrap();
    let direct = direct_engine.compile(&net).unwrap();
    let mut ctx = direct.new_ctx();
    for (tenant, round, s) in &outs {
        let input = net.random_input(DAEMON_INPUT_MAG, *round);
        direct.run(&mut ctx, &input).unwrap();
        assert_eq!(
            s.outputs[0].data,
            ctx.output().data,
            "daemon output for tenant {tenant} round {round} must match a direct run"
        );
    }

    // Same cycles, different energy across the two pricing sessions.
    let cold_run = &outs[0].2;
    let hot_run = &outs[1].2;
    assert_eq!(cold_run.run_cycles_per_inf, hot_run.run_cycles_per_inf);
    assert!(
        hot_run.run_uj_per_inf > cold_run.run_uj_per_inf,
        "the hot model must price the same run higher"
    );

    // Registry: one entry + one compile per tenant, each tenant's
    // second request hits its own entry — no cross-tenant traffic is
    // arithmetically possible with these counters.
    let reg = daemon.registry().stats();
    assert_eq!((reg.misses, reg.hits, reg.compiles, reg.entries), (2, 2, 2, 2));
    assert_eq!(reg.evictions, 0);

    // Per-tenant priced energy must match an independent planner twin.
    let stats = daemon.stats();
    assert_eq!(stats.served_requests, 4);
    assert_eq!(stats.served_inferences, 4);
    for (name, model) in [("cold", EnergyModel::default()), ("hot", hot_model())] {
        let twin = EngineBuilder::new().energy_model(model).workers(1).build().unwrap();
        let plan = plan_network(twin.planner(), &net, PlanObjective::Latency).unwrap();
        let row = stats.tenants.iter().find(|t| t.name == name).unwrap();
        assert_eq!(row.counters.requests, 2);
        assert_eq!(row.counters.inferences, 2);
        assert_eq!(row.counters.priced_cycles, 2 * plan.total_cycles);
        let expect_uj = 2.0 * plan.total_energy_uj;
        assert!(
            (row.counters.priced_uj - expect_uj).abs() <= 1e-9 * expect_uj.abs(),
            "tenant {name}: priced {} uJ, planner twin says {}",
            row.counters.priced_uj,
            expect_uj
        );
    }
    daemon.shutdown();
}

/// The degradation ladder over a live daemon: a deadline that fits one
/// inference but not four serves batch-1 under `Degrade`, rejects
/// under a per-request `Reject` override, and the stats record both.
#[test]
fn deadline_degrades_or_rejects_per_policy() {
    let daemon = Daemon::builder().workers(1).batch(1).build();
    let tenant = daemon.tenant("t").unwrap();
    let net = tiny_net(5);
    let plan = plan_network(tenant.engine().planner(), &net, PlanObjective::Latency).unwrap();
    let one_us = plan.total_cycles as f64 / tenant.engine().energy_model().clock_hz * 1e6;

    let mut req = InferRequest::new("t", tiny_spec(5));
    req.count = 4;
    req.objective = PlanObjective::Energy;
    req.deadline_us = Some(1.5 * one_us);
    let s = served(daemon.submit(req.clone()).unwrap());
    assert_eq!(s.count, 1, "the ladder must cut the batch to fit");
    assert!(s.degrade_steps.contains(&"batch-1"), "{:?}", s.degrade_steps);
    assert_eq!(s.objective, PlanObjective::Latency, "energy remaps to latency first");

    req.admission = Some(AdmissionPolicy::Reject);
    match daemon.submit(req).unwrap() {
        Outcome::Rejected(r) => {
            assert_eq!(r.kind, "deadline");
            assert!(r.modeled_us + r.wait_us > r.deadline_us);
        }
        Outcome::Served(s) => panic!("Reject policy must not degrade (got count {})", s.count),
    }

    let stats = daemon.stats();
    assert_eq!(stats.degraded, 1);
    assert_eq!(stats.rejected, 1);
    let row = &stats.tenants[0];
    assert_eq!((row.counters.degraded, row.counters.rejected), (1, 1));
    daemon.shutdown();
}

/// A count-8 request on a batch-4 daemon rides two 4-lane walks: the
/// walk counters prove the batching, and every lane's output still
/// matches the scalar recipe.
#[test]
fn multi_inference_requests_batch_lanes() {
    let daemon = Daemon::builder().workers(1).batch(4).build();
    let mut req = InferRequest::new("t", tiny_spec(21));
    req.count = 8;
    req.input_seed = 100;
    req.collect_outputs = true;
    let s = served(daemon.submit(req).unwrap());
    assert_eq!(s.count, 8);
    assert_eq!(s.walk_lanes, 8, "all lanes of the request share the walk group");
    assert_eq!(s.outputs.len(), 8);

    let stats = daemon.stats();
    assert_eq!(stats.walks, 2, "8 lanes through batch-4 = two walks");
    assert_eq!(stats.walk_lanes, 8);
    assert_eq!(stats.served_inferences, 8);

    // Lane i corresponds to input_seed + i, bit-exactly.
    let net = tiny_net(21);
    let engine = EngineBuilder::new().workers(1).build().unwrap();
    let direct = engine.compile(&net).unwrap();
    let mut ctx = direct.new_ctx();
    for (i, out) in s.outputs.iter().enumerate() {
        let input = net.random_input(DAEMON_INPUT_MAG, 100 + i as u64);
        direct.run(&mut ctx, &input).unwrap();
        assert_eq!(out.data, ctx.output().data, "lane {i}");
    }
    daemon.shutdown();
}

/// The wire protocol driven in-process through `tcp::handle_line` —
/// no socket required: miss then hit, structured rejections, bad
/// requests, register and stats shapes.
#[test]
fn protocol_handle_line_round_trip() {
    let daemon = Daemon::builder().workers(1).batch(2).build();
    let infer = r#"{"op":"infer","tenant":"t","depth":1,"c0":2,"k":2,"hw":6,"net_seed":3}"#;

    let (resp, shutdown) = tcp::handle_line(&daemon, infer);
    assert!(!shutdown);
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true), "{resp:?}");
    assert_eq!(resp.req_str("cache").unwrap(), "miss");
    assert_eq!(resp.req_i64("count").unwrap(), 1);

    let (resp, _) = tcp::handle_line(&daemon, infer);
    assert_eq!(resp.req_str("cache").unwrap(), "hit");

    // An impossible deadline with reject policy: a structured error,
    // not a panic and not a served response.
    let reject = r#"{"op":"infer","tenant":"t","depth":1,"c0":2,"k":2,"hw":6,"net_seed":3,
                     "deadline_us":0.001,"admission":"reject"}"#;
    let (resp, _) = tcp::handle_line(&daemon, &reject.replace('\n', " "));
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(false));
    let err = resp.get("error").unwrap();
    assert_eq!(err.req_str("kind").unwrap(), "deadline");
    assert!(err.get("modeled_us").unwrap().as_f64().unwrap() > 0.001);

    // Malformed and unknown requests degrade to bad-request errors.
    for bad in ["not json at all", r#"{"op":"zap"}"#, r#"{"op":"infer","count":0}"#] {
        let (resp, shutdown) = tcp::handle_line(&daemon, bad);
        assert!(!shutdown);
        let ok = resp.get("ok").and_then(|v| v.as_bool());
        assert_eq!(ok, Some(false), "input {bad:?} must fail cleanly: {resp:?}");
    }

    // Register echoes the session fingerprint; stats carries both the
    // registry block and the per-tenant rows.
    let (resp, _) =
        tcp::handle_line(&daemon, r#"{"op":"register","tenant":"hot","e_mem_access_pj":99.0}"#);
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true));
    assert!(resp.req_str("session_fp").unwrap().starts_with("0x"));

    let (resp, _) = tcp::handle_line(&daemon, r#"{"op":"stats"}"#);
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true));
    assert!(resp.get("registry").unwrap().req_i64("misses").unwrap() >= 1);
    assert!(resp.get("tenants").unwrap().get("t").is_some());
    assert!(resp.get("tenants").unwrap().get("hot").is_some());

    let (resp, shutdown) = tcp::handle_line(&daemon, r#"{"op":"shutdown"}"#);
    assert!(shutdown);
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true));
    daemon.shutdown();
}

/// The real TCP transport: serve on an OS-assigned port, drive a
/// miss/hit pair and a stats query from a client socket, then shut the
/// daemon down over the wire and join the serve thread.
#[test]
fn tcp_serve_round_trip() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    let daemon = Arc::new(Daemon::builder().workers(1).batch(2).build());
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = {
        let daemon = daemon.clone();
        std::thread::spawn(move || tcp::serve(daemon, listener))
    };

    let mut request = |line: &str| -> openedge_cgra::util::json::Json {
        let stream = TcpStream::connect(addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        writeln!(writer, "{line}").unwrap();
        writer.flush().unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        openedge_cgra::util::json::parse(resp.trim()).unwrap()
    };

    let infer = r#"{"op":"infer","tenant":"t","depth":1,"c0":2,"k":2,"hw":6,"net_seed":3}"#;
    let resp = request(infer);
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true), "{resp:?}");
    assert_eq!(resp.req_str("cache").unwrap(), "miss");
    let resp = request(infer);
    assert_eq!(resp.req_str("cache").unwrap(), "hit");

    let resp = request(r#"{"op":"stats"}"#);
    assert_eq!(resp.req_i64("served_requests").unwrap(), 2);

    let resp = request(r#"{"op":"shutdown"}"#);
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true));
    server.join().unwrap().unwrap();

    // The daemon refuses work after the wire shutdown.
    assert!(daemon.submit(InferRequest::new("t", tiny_spec(3))).is_err());
}
