//! Differential tests for the decode/execute engine: the decoded µop
//! interpreter must be bit-exact against (a) the pre-refactor enum
//! interpreter (`Cgra::run_reference`) in stats *and* memory effects,
//! and (b) the golden `conv::golden` model through the full kernel
//! drivers — on randomized shapes via the `prop` harness.

use openedge_cgra::cgra::{clear_decode_cache, decode, Cgra, CgraConfig, Memory};
use openedge_cgra::conv::{conv2d, random_input, random_weights, ConvShape};
use openedge_cgra::engine::{ConvRequest, EngineBuilder};
use openedge_cgra::kernels::{wp, Mapping, MemLayout};
use openedge_cgra::prop::{forall, usize_in, Gen, Rng};

fn shape_gen(max_ch: usize, max_sp: usize) -> Gen<ConvShape> {
    usize_in(1, max_ch)
        .pair(usize_in(1, max_ch))
        .pair(usize_in(1, max_sp).pair(usize_in(1, max_sp)))
        .map(|((c, k), (ox, oy))| ConvShape::new3x3(c, k, ox, oy))
}

/// Run one WP launch program through both engines from identical
/// memories; compare stats and the full memory image.
fn diff_one_launch(shape: &ConvShape, k: usize, ci: usize, seed: u64) -> Result<(), String> {
    let cfg = CgraConfig::default();
    let layout = MemLayout::new(shape, 0, &cfg).map_err(|e| e.to_string())?;
    let mut rng = Rng::new(seed);
    let input = random_input(shape, 25, &mut rng);
    let weights = random_weights(shape, 9, &mut rng);
    let cgra = Cgra::new(cfg.clone()).map_err(|e| e.to_string())?;

    let prog = wp::build_program(shape, &layout, wp::WpLaunch { k, ci, acc: ci > 0 });
    let dp = decode(&prog);

    let mut m_ref = Memory::new(cfg.mem_words, cfg.n_banks);
    m_ref.poke_slice(layout.input, &input.data);
    m_ref.poke_slice(layout.weights, &weights.data);
    let mut m_dec = m_ref.clone();

    let s_ref = cgra.run_reference(&prog, &mut m_ref).map_err(|e| format!("ref: {e:#}"))?;
    let s_dec = cgra.run_decoded(&dp, &mut m_dec).map_err(|e| format!("dec: {e:#}"))?;

    if s_ref != s_dec {
        return Err(format!(
            "stats diverge on {shape} launch (k={k}, ci={ci}):\n ref {s_ref:?}\n dec {s_dec:?}"
        ));
    }
    if m_ref.peek_slice(0, layout.total_words) != m_dec.peek_slice(0, layout.total_words) {
        let a = m_ref.peek_slice(0, layout.total_words);
        let b = m_dec.peek_slice(0, layout.total_words);
        let i = a.iter().zip(b).position(|(x, y)| x != y).unwrap();
        return Err(format!(
            "memory diverges on {shape} at word {i}: {} != {}",
            a[i], b[i]
        ));
    }
    Ok(())
}

/// Decoded engine == reference interpreter, step-for-step (`RunStats`
/// including steps, cycles/energy inputs and contention "collisions")
/// and word-for-word, on randomized WP launch programs.
#[test]
fn prop_decoded_equals_reference_on_wp_launches() {
    forall("decoded == reference (WP launches)", 20, &shape_gen(4, 7), |s| {
        diff_one_launch(s, 0, 0, 900 + s.c as u64)?;
        if s.c > 1 {
            diff_one_launch(s, s.k - 1, 1, 901 + s.oy as u64)?;
        }
        Ok(())
    });
}

/// Decoded engine drives every mapping to the same bit-exact result as
/// the golden direct convolution on randomized shapes.
#[test]
fn prop_decoded_engine_matches_golden_conv() {
    forall("decoded kernels == golden", 16, &shape_gen(5, 6), |s| {
        let mut rng = Rng::new(4400 + s.k as u64);
        let input = random_input(s, 40, &mut rng);
        let weights = random_weights(s, 10, &mut rng);
        let golden = conv2d(s, &input, &weights);
        let engine = EngineBuilder::new().build().map_err(|e| e.to_string())?;
        for m in [Mapping::Wp, Mapping::OpIm2col, Mapping::OpDirect] {
            let res = engine
                .submit(&ConvRequest::with_data(*s, m, input.clone(), weights.clone()))
                .map_err(|e| format!("{m}: {e:#}"))?;
            if res.output.data != golden.data {
                return Err(format!("{m} disagrees with golden on {s}"));
            }
        }
        Ok(())
    });
}

/// The decode cache returns hits for repeated launches and the cached
/// decode runs identically to a fresh one.
#[test]
fn decode_cache_roundtrip_is_exact() {
    let shape = ConvShape::new3x3(2, 2, 4, 4);
    let cfg = CgraConfig::default();
    let layout = MemLayout::new(&shape, 0, &cfg).unwrap();
    let mut rng = Rng::new(7);
    let input = random_input(&shape, 10, &mut rng);
    let weights = random_weights(&shape, 5, &mut rng);
    let cgra = Cgra::new(cfg.clone()).unwrap();

    // The decode-cache hit *counters* are asserted in the unit test in
    // `cgra::decoded` (with eviction-race tolerance); here we assert
    // the behavioural contract: cached, fresh, and post-clear decodes
    // replay bit-identically.
    let run_once = || {
        let mut mem = Memory::new(cfg.mem_words, cfg.n_banks);
        mem.poke_slice(layout.input, &input.data);
        mem.poke_slice(layout.weights, &weights.data);
        // `run` goes through decode_cached internally.
        let prog = wp::build_program(&shape, &layout, wp::WpLaunch { k: 0, ci: 0, acc: false });
        cgra.run(&prog, &mut mem).unwrap()
    };
    let a = run_once();
    let b = run_once();
    assert_eq!(a, b, "cached decode must replay identically");

    // Clearing the cache must not change behaviour, only stats.
    clear_decode_cache();
    let c = run_once();
    assert_eq!(a, c);
}

/// Full WP convolutions agree between engines at the aggregate level
/// (the reference engine is only reachable launch-by-launch, so compare
/// the end-to-end result against golden plus a launch-level diff above).
#[test]
fn wp_conv_exact_after_decode_refactor() {
    let shape = ConvShape::baseline();
    let mut rng = Rng::new(77);
    let input = random_input(&shape, 30, &mut rng);
    let weights = random_weights(&shape, 9, &mut rng);
    let cgra = Cgra::new(CgraConfig::default()).unwrap();
    let out = wp::run(&cgra, &shape, &input, &weights).unwrap();
    let golden = conv2d(&shape, &input, &weights);
    assert_eq!(out.output.data, golden.data);
    assert_eq!(out.latency.launches, 256);
}
