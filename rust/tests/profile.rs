//! End-to-end invariants of the cycle-attribution profiler
//! (DESIGN.md §12), asserted across every execution tier: raw
//! [`Cgra::run`] walks, the reference interpreter, one-shot kernel
//! drivers, and warm scalar/batched `CompiledNet` inference.
//!
//! This file deliberately holds a single `#[test]`: the profiler's
//! enabled flag and session aggregates are process-wide, so any
//! concurrently running test in the same binary would race the
//! free-when-off assertions. Other integration binaries are separate
//! processes and cannot interfere.

use openedge_cgra::cgra::{Cgra, CgraConfig, Memory};
use openedge_cgra::conv::{self, ConvShape};
use openedge_cgra::engine::EngineBuilder;
use openedge_cgra::isa::N_PES;
use openedge_cgra::kernels::wp::{self, WpLaunch};
use openedge_cgra::kernels::MemLayout;
use openedge_cgra::nn;
use openedge_cgra::obs::profile;
use openedge_cgra::prop::Rng;

#[test]
fn attribution_invariants_across_all_execution_tiers() {
    let shape = ConvShape::new3x3(3, 4, 6, 6);
    let mut rng = Rng::new(0x51ce);
    let input = conv::random_input(&shape, 64, &mut rng);
    let weights = conv::random_weights(&shape, 64, &mut rng);

    // Compile-once artifact prepared *before* any profiling session so
    // the profiled runs below are pure warm replays.
    let engine = EngineBuilder::new().workers(1).private_cache().build().unwrap();
    let net = nn::build_preset("mobilenet-mini", 7).unwrap();
    let compiled = engine.compile(&net).unwrap();
    let mut ctx = compiled.new_ctx();
    let net_input = net.random_input(8, 3);
    let unprofiled = compiled.run(&mut ctx, &net_input).unwrap();

    // -- Free-when-off ------------------------------------------------
    // Without a session nothing is recorded anywhere: no last-walk
    // snapshot, no attribution on inference results.
    assert!(!profile::enabled());
    let base = wp::run(engine.cgra(), &shape, &input, &weights).unwrap();
    assert!(profile::take_last_walk().is_none(), "no session ⇒ no walk snapshots");
    assert!(unprofiled.profile.is_none(), "no session ⇒ no attribution on InferRun");

    let session = profile::session();

    // -- Attribution sums, per-PE occupancy, per-bank histograms ------
    // One frame around the full WP conv: the delta must account for
    // every simulator cycle exactly — same totals as RunStats, class
    // cycles summing with no remainder, busy+idle covering each PE.
    let fr = profile::frame();
    let profiled = wp::run(engine.cgra(), &shape, &input, &weights).unwrap();
    let d = fr.finish().expect("profiled conv must produce a frame delta");
    assert_eq!(
        profiled.output.data, base.output.data,
        "profiling must not change functional results"
    );
    assert_eq!(
        (profiled.cgra_stats.cycles, profiled.cgra_stats.steps),
        (base.cgra_stats.cycles, base.cgra_stats.steps),
        "profiling must not change modeled timing"
    );
    assert_eq!(d.walks, (shape.k * shape.c) as u64, "WP runs one walk per (k, ci) launch");
    assert_eq!(d.cycles, base.cgra_stats.cycles, "frame cycles must equal RunStats cycles");
    assert_eq!(d.steps, base.cgra_stats.steps, "frame steps must equal RunStats steps");
    assert_eq!(
        d.class_cycles.iter().sum::<u64>(),
        d.cycles,
        "bottleneck classes must partition the walk cycles exactly"
    );
    for pe in 0..N_PES {
        assert_eq!(
            d.busy[pe] + d.idle[pe],
            d.cycles,
            "busy + idle must cover every cycle for PE {pe}"
        );
    }
    let cfg = CgraConfig::default();
    assert_eq!(d.bank_conflicts.len(), cfg.n_banks, "one conflict histogram per bank");
    assert!(d.hi_water_words > 0 && d.hi_water_words <= cfg.mem_words);

    // -- Reference interpreter ≡ decoded executor ---------------------
    // The differential baseline attributes the exact same delta as the
    // decode/execute engine for the same launch.
    let layout = MemLayout::new(&shape, 0, &cfg).unwrap();
    let prog = wp::build_program(&shape, &layout, WpLaunch { k: 0, ci: 0, acc: false });
    let cgra = Cgra::new(cfg.clone()).unwrap();
    let seed_mem = |mem: &mut Memory| {
        mem.poke_slice(layout.input, &input.data);
        mem.poke_slice(layout.weights, &weights.data);
    };
    let mut mem_dec = Memory::new(cfg.mem_words, cfg.n_banks);
    seed_mem(&mut mem_dec);
    let s_dec = cgra.run(&prog, &mut mem_dec).unwrap();
    let d_dec = profile::take_last_walk().expect("decoded walk snapshot");
    let mut mem_ref = Memory::new(cfg.mem_words, cfg.n_banks);
    seed_mem(&mut mem_ref);
    let s_ref = cgra.run_reference(&prog, &mut mem_ref).unwrap();
    let d_ref = profile::take_last_walk().expect("reference walk snapshot");
    assert_eq!(s_dec.cycles, s_ref.cycles);
    assert_eq!(d_dec, d_ref, "reference and decoded walks must attribute identically");

    // -- Scalar ≡ batch, lane for lane --------------------------------
    // A batched walk is attributed once and reported per inference:
    // the delta on a batched InferRun is bit-identical to the scalar
    // one, full and ragged alike, over *different* lane inputs.
    let srun = compiled.run(&mut ctx, &net_input).unwrap();
    let sd = srun.profile.clone().expect("profiled scalar run attaches attribution");
    assert_eq!(
        srun.total_cycles, unprofiled.total_cycles,
        "profiling must not change compiled-run modeled cycles"
    );
    assert_eq!(
        srun.total_energy_uj.to_bits(),
        unprofiled.total_energy_uj.to_bits(),
        "profiling must not change compiled-run modeled energy, bit for bit"
    );
    assert_eq!(
        sd.class_cycles.iter().sum::<u64>(),
        sd.cycles,
        "inference attribution must partition walk cycles exactly"
    );
    let lanes: Vec<_> = (0..3u64).map(|l| net.random_input(8, 20 + l)).collect();
    let mut bctx = compiled.new_batch_ctx(3);
    let brun = compiled.run_batch(&mut bctx, &lanes).unwrap();
    assert_eq!(
        brun.profile.as_ref(),
        Some(&sd),
        "batched attribution must equal scalar attribution lane for lane"
    );
    let ragged = compiled.run_batch(&mut bctx, &lanes[..2]).unwrap();
    assert_eq!(ragged.profile.as_ref(), Some(&sd), "ragged batches attribute identically");

    // -- Session aggregates -------------------------------------------
    let prof = session.finish();
    assert!(!profile::enabled(), "finishing the session must disable profiling");
    assert!(profile::take_last_walk().is_none(), "finish clears walk snapshots");
    assert_eq!(
        prof.total.class_cycles.iter().sum::<u64>(),
        prof.total.cycles,
        "the session-wide total obeys the partition invariant too"
    );
    assert!(
        !prof.by_mapping.is_empty(),
        "compiled walks must aggregate under their mapping labels"
    );
    for (label, delta) in prof.by_mapping.iter().chain(prof.by_layer.iter()) {
        assert_eq!(
            delta.class_cycles.iter().sum::<u64>(),
            delta.cycles,
            "aggregate '{label}' must partition its cycles exactly"
        );
    }
    assert!(
        prof.by_layer.keys().all(|k| k.starts_with('L')),
        "layer aggregates are keyed by position"
    );
    assert!(!prof.by_layer.is_empty(), "compiled inference must aggregate per layer");

    // A fresh session starts from zero — aggregates do not leak across
    // sessions.
    let s2 = profile::session();
    let p2 = s2.finish();
    assert_eq!(p2.total.walks, 0);
    assert!(p2.by_mapping.is_empty() && p2.by_layer.is_empty());
}
