//! Bench: plans per second — the analytical cost model vs the
//! simulator, on the paper's baseline layer.
//!
//! Four measurements answer "how fast can a metrics-only question be
//! answered?":
//!
//!   1. cold simulation — a fresh private-cache engine simulates the
//!      layer (what every sweep point cost before the point cache),
//!   2. cache-hot `submit_report` — the memoized simulator answer
//!      (PR 1/2's fast path: one lookup, but only for seen points),
//!   3. cold planner — a fresh engine calibrates (a handful of probe
//!      launches) and predicts: the first-question cost for an
//!      *unseen* point,
//!   4. memoized planner `plan` — repeated cost-model answers
//!      (the `submit_planned` steady state: a lock + clone).
//!
//! `cargo bench --bench planner_vs_sim`

use openedge_cgra::benchkit::Bench;
use openedge_cgra::conv::ConvShape;
use openedge_cgra::engine::{ConvRequest, EngineBuilder};
use openedge_cgra::kernels::Mapping;

fn main() {
    let shape = ConvShape::baseline();
    let req = ConvRequest::seeded(shape, Mapping::Wp, 7);
    let b = Bench::default();

    // 1. Cold simulation: new engine + private cache every iteration.
    b.run("cold simulation (submit_report)", Some(1.0), || {
        let e = EngineBuilder::new().workers(1).private_cache().build().expect("engine");
        e.submit_report(&req).expect("simulate")
    });

    // 2. Cache-hot simulator answer.
    let hot = EngineBuilder::new().workers(1).private_cache().build().expect("engine");
    hot.submit_report(&req).expect("warm the point cache");
    b.run("cache-hot submit_report", Some(1.0), || hot.submit_report(&req).expect("hit"));

    // 3. Cold planner: new engine, probes run every iteration.
    b.run("cold planner (probe calibration)", Some(1.0), || {
        let e = EngineBuilder::new().workers(1).private_cache().build().expect("engine");
        e.plan(&shape, Mapping::Wp).expect("plan")
    });

    // 4. Memoized planner answer.
    hot.plan(&shape, Mapping::Wp).expect("warm the planner memo");
    b.run("memoized planner plan", Some(1.0), || hot.plan(&shape, Mapping::Wp).expect("plan"));

    let stats = hot.planner().stats();
    println!(
        "\nplanner calibrated from {} probe launches; {} of {} estimates were memo hits",
        stats.probe_launches, stats.memo_hits, stats.estimates
    );
}
