//! Bench: cold-start latency — compile-from-source vs load-from-disk
//! (DESIGN.md §13, EXPERIMENTS.md E17).
//!
//! For each preset the serving fleet actually deploys
//! (`mobilenet-mini`, `vgg-mini`, `paper-baseline`):
//!
//!   1. **compile** — `Engine::compile` per sample: planner
//!      resolution, program building, µop decoding, weight baking,
//!   2. **load** — `CompiledNet::load` of the serialized artifact per
//!      sample: header + manifest validation, checksum, payload decode
//!      — zero builds, zero decodes, zero planner calls by
//!      construction (pinned by `tests/compiled_counters.rs`).
//!
//! Before timing, the loaded artifact is gated on producing the same
//! modeled cycles as the compiled one. The printed ratio is the
//! first-inference win an AOT artifact buys a restarting process.
//!
//! `cargo bench --bench cold_start`

use openedge_cgra::benchkit::{Bench, ResultsWriter};
use openedge_cgra::engine::{CompiledNet, EngineBuilder};
use openedge_cgra::nn;

fn main() {
    let engine = EngineBuilder::new().private_cache().build().expect("engine");
    let dir = std::env::temp_dir().join(format!("cgra-cold-start-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("scratch dir");

    let b = Bench::new(1, 5);
    let mut results = ResultsWriter::new("cold_start");

    for preset in ["mobilenet-mini", "vgg-mini", "paper-baseline"] {
        let net = nn::build_preset(preset, 7).expect("preset");
        let path = dir.join(format!("{preset}.cgrart"));

        let compiled = engine.compile(&net).expect("compile");
        let info = compiled.save(&path).expect("save");

        // Gate: the artifact replays identically before we time it.
        let input = net.random_input(8, 11);
        let (loaded, _) = CompiledNet::load(&engine, &path).expect("load");
        let (mut ca, mut cb) = (compiled.new_ctx(), loaded.new_ctx());
        let ra = compiled.run(&mut ca, &input).expect("run compiled");
        let rb = loaded.run(&mut cb, &input).expect("run loaded");
        assert_eq!(ra.total_cycles, rb.total_cycles, "{preset}: loaded artifact diverged");
        assert_eq!(ca.output().data, cb.output().data, "{preset}: outputs diverged");

        let compile = b.run(&format!("{preset}: Engine::compile (cold)"), None, || {
            engine.compile(&net).expect("compile")
        });
        let load = b.run(&format!("{preset}: CompiledNet::load (disk)"), None, || {
            CompiledNet::load(&engine, &path).expect("load")
        });

        let speedup = compile.median() / load.median().max(1e-12);
        results.row(&format!("{preset}_compile_ms"), compile.median() * 1e3);
        results.row(&format!("{preset}_load_ms"), load.median() * 1e3);
        results.row(&format!("{preset}_load_speedup"), speedup);
        println!(
            "{preset}: compile {:.2} ms vs load {:.2} ms -> {speedup:.1}x faster cold start \
             ({} bytes on disk, checksum {:016x})\n",
            compile.median() * 1e3,
            load.median() * 1e3,
            info.file_bytes,
            info.checksum,
        );
    }

    results.flush();
    std::fs::remove_dir_all(&dir).ok();
}
