//! Bench: regenerate Figure 4 (energy vs latency, baseline layer, all
//! five strategies) — the paper's headline experiment — and time the
//! individual mappings.
//!
//! `cargo bench --bench fig4_energy_latency`

use openedge_cgra::benchkit::Bench;
use openedge_cgra::conv::{random_input, random_weights, ConvShape};
use openedge_cgra::engine::{ConvRequest, EngineBuilder};
use openedge_cgra::kernels::Mapping;
use openedge_cgra::prop::Rng;
use openedge_cgra::report;

fn main() {
    let engine = EngineBuilder::new().build().expect("engine");
    let fig = report::fig4(&engine).expect("fig4");
    println!("{}", fig.text);

    // Per-mapping simulation throughput (simulated MACs per host
    // second). Explicit tensors bypass the point cache, so these
    // timings measure real simulation.
    let shape = ConvShape::baseline();
    let mut rng = Rng::new(4);
    let input = random_input(&shape, 30, &mut rng);
    let weights = random_weights(&shape, 9, &mut rng);
    let b = Bench::new(1, 3);
    for m in Mapping::ALL {
        let req = ConvRequest::with_data(shape, m, input.clone(), weights.clone());
        b.run(
            &format!("simulate baseline layer / {}", m.label()),
            Some(shape.macs() as f64),
            || engine.submit(&req).expect("run"),
        );
    }
}
