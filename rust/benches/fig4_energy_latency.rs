//! Bench: regenerate Figure 4 (energy vs latency, baseline layer, all
//! five strategies) — the paper's headline experiment — and time the
//! individual mappings.
//!
//! `cargo bench --bench fig4_energy_latency`

use openedge_cgra::benchkit::Bench;
use openedge_cgra::cgra::{Cgra, CgraConfig};
use openedge_cgra::conv::{random_input, random_weights, ConvShape};
use openedge_cgra::coordinator::default_workers;
use openedge_cgra::kernels::{run_mapping, Mapping};
use openedge_cgra::prop::Rng;
use openedge_cgra::report;

fn main() {
    let cfg = CgraConfig::default();
    let fig = report::fig4(&cfg, default_workers()).expect("fig4");
    println!("{}", fig.text);

    // Per-mapping simulation throughput (simulated MACs per host second).
    let shape = ConvShape::baseline();
    let mut rng = Rng::new(4);
    let input = random_input(&shape, 30, &mut rng);
    let weights = random_weights(&shape, 9, &mut rng);
    let cgra = Cgra::new(cfg).expect("cgra");
    // run_mapping itself is uncached (only run_all_mappings memoizes),
    // so these per-mapping timings measure real simulation.
    let b = Bench::new(1, 3);
    for m in Mapping::ALL {
        b.run(
            &format!("simulate baseline layer / {}", m.label()),
            Some(shape.macs() as f64),
            || run_mapping(&cgra, m, &shape, &input, &weights).expect("run"),
        );
    }
}
