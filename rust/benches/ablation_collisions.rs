//! Ablation: how much of WP's advantage comes from *collision avoidance*?
//!
//! The paper attributes WP's win to "the reduced number of memory
//! accesses and their distribution over time [which] avoids collisions
//! between PEs". This ablation re-runs Figure 4's latency comparison
//! with the contention model progressively disabled:
//!
//!   A. calibrated model (DMA-port serialization + bank conflicts)
//!   B. no bank conflicts (bank_penalty = 0)
//!   C. ideal memory (mem_latency = 1, no serialization effect beyond
//!      one cycle per access)
//!
//! If the paper's causal story holds, the WP-vs-lane-mapping gap should
//! shrink dramatically from A to C.
//!
//! `cargo bench --bench ablation_collisions`

use openedge_cgra::cgra::CgraConfig;
use openedge_cgra::conv::{random_input, random_weights, ConvShape};
use openedge_cgra::engine::{ConvRequest, EngineBuilder};
use openedge_cgra::kernels::Mapping;
use openedge_cgra::prop::Rng;
use openedge_cgra::util::fmt::Table;

fn main() {
    let shape = ConvShape::baseline();
    let mut rng = Rng::new(12);
    let input = random_input(&shape, 20, &mut rng);
    let weights = random_weights(&shape, 9, &mut rng);

    let mut variants: Vec<(&str, CgraConfig)> = Vec::new();
    variants.push(("A: calibrated (ports+banks)", CgraConfig::default()));
    let mut b = CgraConfig::default();
    b.bank_penalty = 0;
    variants.push(("B: no bank conflicts", b));
    let mut c = CgraConfig::default();
    c.bank_penalty = 0;
    c.mem_latency = 1;
    variants.push(("C: ideal memory", c));

    let mut table =
        Table::new(&["contention model", "mapping", "cycles", "MAC/cycle", "vs WP"]);
    for (label, cfg) in &variants {
        // One engine session per contention model: the config fingerprint
        // keeps their cache entries apart.
        let engine = EngineBuilder::new().config(cfg.clone()).build().expect("engine");
        let mut wp_cycles = 0u64;
        for m in [Mapping::Wp, Mapping::OpIm2col, Mapping::OpDirect, Mapping::Ip] {
            let req = ConvRequest::with_data(shape, m, input.clone(), weights.clone());
            let res = engine.submit(&req).expect("run");
            let cycles = res.report.latency_cycles;
            if m == Mapping::Wp {
                wp_cycles = cycles;
            }
            table.row(vec![
                label.to_string(),
                m.label().into(),
                cycles.to_string(),
                format!("{:.3}", res.report.mac_per_cycle),
                format!("{:.2}x", cycles as f64 / wp_cycles as f64),
            ]);
        }
    }
    println!("{}", table.render());
    println!(
        "reading the table: bank conflicts (A->B) hit the lane mappings hardest\n\
         (their 16-PE same-address bursts collide; WP barely moves) — the paper's\n\
         §3.1 collision story. Under ideal memory (C) a structural gap remains\n\
         (per-pixel prologue/epilogue of the lane loops vs WP's 4-slot pipeline),\n\
         and Im2col-IP stays flat: it is launch/CPU-im2col bound, not memory\n\
         bound — exactly why the paper singles it out."
    );
}
