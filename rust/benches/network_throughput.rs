//! Bench: end-to-end network throughput through the `nn` layer-graph
//! subsystem, in layers per second — how fast the stack can move a
//! MobileNet-style edge network through the simulated CGRA.
//!
//! Three measurements over the same preset:
//!
//!   1. sequential execution (`nn::run_network` with a 1-thread pool —
//!      every group submission serialized),
//!   2. batched execution (default worker pool — grouped layers fan
//!      their independent per-group convolutions over the workers),
//!   3. plan-only (`nn::plan_network` — the analytical cost model
//!      prices every layer, nothing is simulated; cache-hot after the
//!      first call thanks to the planner memo).
//!
//! `cargo bench --bench network_throughput`

use openedge_cgra::benchkit::Bench;
use openedge_cgra::coordinator::default_workers;
use openedge_cgra::engine::EngineBuilder;
use openedge_cgra::nn;
use openedge_cgra::planner::PlanObjective;

fn main() {
    let preset = "mobilenet-mini";
    let net = nn::build_preset(preset, 7).expect("preset");
    let input = net.random_input(8, 7);
    let n_layers = net.layers.len() as f64;
    println!(
        "network '{preset}': {} layers, {} true MACs, {} workers\n",
        net.layers.len(),
        net.macs(),
        default_workers()
    );

    let b = Bench::new(1, 5);

    // 1. Sequential: one worker, group submissions serialized.
    let seq_engine = EngineBuilder::new().workers(1).private_cache().build().expect("engine");
    let seq = b.run("run_network (sequential)", Some(n_layers), || {
        nn::run_network(&seq_engine, &net, &input).expect("run")
    });

    // 2. Batched: the default pool fans grouped layers out.
    let pool_engine = EngineBuilder::new()
        .workers(default_workers())
        .private_cache()
        .build()
        .expect("engine");
    let batched = b.run("run_network (batched)", Some(n_layers), || {
        nn::run_network(&pool_engine, &net, &input).expect("run")
    });

    // 3. Plan-only: the cost model instead of the simulator.
    let planned = b.run("plan_network (plan-only)", Some(n_layers), || {
        nn::plan_network(pool_engine.planner(), &net, PlanObjective::Latency).expect("plan")
    });

    println!(
        "\nbatched vs sequential: {:.2}x layers/s ({:.1} -> {:.1}); \
         plan-only serves {:.0} layers/s ({:.0}x over simulating)",
        seq.median() / batched.median(),
        n_layers / seq.median(),
        n_layers / batched.median(),
        n_layers / planned.median(),
        batched.median() / planned.median(),
    );
}
