//! Bench: end-to-end network throughput through the `nn` layer-graph
//! subsystem — layers/s and inferences/s over a MobileNet-style edge
//! network.
//!
//! Since the compile-once refactor, `nn::run_network` compiles (and
//! golden-verifies) on every call, and parallelism lives *across*
//! inferences (one `Arc<CompiledNet>`, one `NetCtx` per worker) rather
//! than inside one. The measurements reflect that architecture:
//!
//!   1. per-call path (`nn::run_network` — compile + golden verify +
//!      run on every call: the pre-refactor per-inference cost),
//!   2. plan-only (`nn::plan_network` — the analytical cost model
//!      prices every layer, nothing is simulated; cache-hot after the
//!      first call thanks to the planner memo),
//!   3. compiled warm run (`CompiledNet::run`, one context — the
//!      single-stream serving steady state),
//!   4. compiled parallel serving (one `Arc`-shared artifact, a batch
//!      of inferences fanned over the worker pool, one context per
//!      worker).
//!
//! Reported both as layers/s and inferences/s so the compile-once
//! amortization win lands in the perf trajectory. See
//! `serving_throughput` for the cold-compile amortization curve.
//!
//! `cargo bench --bench network_throughput`

use std::sync::Arc;

use openedge_cgra::benchkit::Bench;
use openedge_cgra::coordinator::{default_workers, run_jobs};
use openedge_cgra::engine::EngineBuilder;
use openedge_cgra::nn;
use openedge_cgra::planner::PlanObjective;

fn main() {
    let preset = "mobilenet-mini";
    let net = nn::build_preset(preset, 7).expect("preset");
    let input = net.random_input(8, 7);
    let n_layers = net.layers.len() as f64;
    let workers = default_workers();
    println!(
        "network '{preset}': {} layers, {} true MACs, {} workers\n",
        net.layers.len(),
        net.macs(),
        workers
    );

    let b = Bench::new(1, 5);
    let engine = EngineBuilder::new().private_cache().build().expect("engine");

    // 1. Per-call path: compile + golden verify + run, every call.
    let per_call = b.run("run_network (compile per call)", Some(n_layers), || {
        nn::run_network(&engine, &net, &input).expect("run")
    });

    // 2. Plan-only: the cost model instead of the simulator.
    let planned = b.run("plan_network (plan-only)", Some(n_layers), || {
        nn::plan_network(engine.planner(), &net, PlanObjective::Latency).expect("plan")
    });

    // 3. Compiled warm run: compile once, replay per sample.
    let compiled = Arc::new(engine.compile(&net).expect("compile"));
    let mut ctx = compiled.new_ctx();
    let warm = b.run("CompiledNet::run (compiled, warm)", Some(n_layers), || {
        compiled.run(&mut ctx, &input).expect("run")
    });

    // 4. Parallel serving: a batch of inferences per sample, fanned
    //    over the pool — one pre-built context per worker.
    let batch = 2 * workers;
    let mut ctxs: Vec<_> = (0..workers).map(|_| compiled.new_ctx()).collect();
    let shard = batch.div_ceil(workers);
    let fan = b.run(
        &format!("CompiledNet::run (x{batch} over {workers} workers)"),
        Some(batch as f64 * n_layers),
        || {
            let jobs: Vec<_> = ctxs
                .iter_mut()
                .map(|ctx| {
                    let compiled = compiled.clone();
                    let input = &input;
                    move || {
                        for _ in 0..shard {
                            compiled.run(ctx, input).expect("run");
                        }
                    }
                })
                .collect();
            run_jobs(workers, jobs)
        },
    );

    println!(
        "\ninferences/s: per-call {:.1} -> compiled warm {:.1} ({:.2}x); \
         plan-only answers {:.0}/s ({:.0}x over simulating)",
        1.0 / per_call.median(),
        1.0 / warm.median(),
        per_call.median() / warm.median(),
        1.0 / planned.median(),
        warm.median() / planned.median(),
    );
    println!(
        "parallel serving: {:.1} inf/s over {workers} workers ({:.2}x one warm stream)",
        batch as f64 / fan.median(),
        (batch as f64 / fan.median()) * warm.median(),
    );
}
