//! Bench: regenerate Figure 3 (operation distribution / utilization of
//! the four mapping strategies on the baseline layer) and time it.
//!
//! `cargo bench --bench fig3_opmix`

use openedge_cgra::benchkit::Bench;
use openedge_cgra::engine::EngineBuilder;
use openedge_cgra::report;

fn main() {
    let engine = EngineBuilder::new().build().expect("engine");

    // Print the figure once (the artifact of this bench)...
    let fig = report::fig3(&engine).expect("fig3");
    println!("{}", fig.text);

    // ...then time the regeneration. The engine's point cache would
    // turn repeat samples into lookups, so clear it inside the timed
    // closure — the bench must measure simulation, not memoization.
    let b = Bench::new(1, 5);
    b.run("report/fig3 (baseline layer, 4 mappings)", None, || {
        engine.cache().clear();
        report::fig3(&engine).expect("fig3")
    });
}
