//! Bench: raw simulator performance — PE-slots per host second on the
//! WP steady-state loop, plus program-generation and decode cost. The
//! target of the §Perf optimization pass (EXPERIMENTS.md): the Fig. 5
//! full sweep must complete in minutes.
//!
//! Reports the decode/execute split win directly: the same WP launch is
//! driven through the pre-refactor enum interpreter
//! (`Cgra::run_reference`, the "before") and the decoded µop engine
//! (`Cgra::run_decoded`, the "after"), and the speedup is printed as a
//! PE-slots-per-second ratio. The two engines are asserted to produce
//! identical `RunStats` before any timing happens.
//!
//! `cargo bench --bench sim_throughput`

use openedge_cgra::benchkit::{Bench, ResultsWriter};
use openedge_cgra::cgra::{decode, decode_cached, BatchMemory, Cgra, CgraConfig, Memory};
use openedge_cgra::conv::{random_input, random_weights, ConvShape};
use openedge_cgra::isa::N_PES;
use openedge_cgra::kernels::{wp, MemLayout};
use openedge_cgra::prop::Rng;

fn main() {
    let cfg = CgraConfig::default();
    let shape = ConvShape::baseline();
    let layout = MemLayout::new(&shape, 0, &cfg).expect("layout");
    let mut rng = Rng::new(1);
    let input = random_input(&shape, 10, &mut rng);
    let weights = random_weights(&shape, 9, &mut rng);
    let cgra = Cgra::new(cfg.clone()).expect("cgra");

    // Steady-state stepping rate: one WP launch, measured in PE slots.
    let prog = wp::build_program(&shape, &layout, wp::WpLaunch { k: 0, ci: 1, acc: true });
    let dp = decode_cached(&prog);
    let mut mem = Memory::new(cfg.mem_words, cfg.n_banks);
    mem.poke_slice(layout.input, &input.data);
    mem.poke_slice(layout.weights, &weights.data);
    let steps = cgra.run_decoded(&dp, &mut mem).expect("run").steps;
    let slots = (steps * N_PES as u64) as f64;

    // Correctness gate before timing: both engines, fresh identical
    // memories, step-for-step identical stats.
    {
        let mut m_ref = Memory::new(cfg.mem_words, cfg.n_banks);
        m_ref.poke_slice(layout.input, &input.data);
        m_ref.poke_slice(layout.weights, &weights.data);
        let mut m_dec = m_ref.clone();
        let s_ref = cgra.run_reference(&prog, &mut m_ref).expect("reference run");
        let s_dec = cgra.run_decoded(&dp, &mut m_dec).expect("decoded run");
        assert_eq!(s_ref, s_dec, "engines diverged — decoded run is not bit-exact");
        println!("engines agree: {} steps, {} cycles, bit-exact stats\n", s_ref.steps, s_ref.cycles);
    }

    let b = Bench::default();

    // BEFORE: the pre-refactor enum-matching interpreter.
    let before = b.run(
        &format!("executor[reference]: WP launch ({steps} steps x {N_PES} PEs)"),
        Some(slots),
        || cgra.run_reference(&prog, &mut mem).expect("run"),
    );

    // AFTER: the decoded µop engine (decode amortized via the cache).
    let after = b.run(
        &format!("executor[decoded]:   WP launch ({steps} steps x {N_PES} PEs)"),
        Some(slots),
        || cgra.run_decoded(&dp, &mut mem).expect("run"),
    );

    let speedup = before.median() / after.median();
    println!(
        "\ndecode/execute split: {:.2}x PE-slots/s on the WP steady-state loop \
         ({:.1}M -> {:.1}M slots/s)\n",
        speedup,
        slots / before.median() / 1e6,
        slots / after.median() / 1e6,
    );
    let mut results = ResultsWriter::new("sim_throughput");
    results.row("reference_slots_per_s", slots / before.median());
    results.row("decoded_slots_per_s", slots / after.median());
    results.row("decoded_speedup", speedup);

    // Batched replay: one shared µop walk across B lane images
    // (DESIGN.md §9) — the walk simulates B lanes' worth of PE slots,
    // so throughput is slots × B per batched run. Gate: the batched
    // walk's per-inference stats equal the scalar decoded run's.
    println!("batched replay (B lanes per shared uop walk):");
    let s_scalar = {
        let mut m = Memory::new(cfg.mem_words, cfg.n_banks);
        m.poke_slice(layout.input, &input.data);
        m.poke_slice(layout.weights, &weights.data);
        cgra.run_decoded(&dp, &mut m).expect("scalar run")
    };
    let mut b1_rate = 0.0f64;
    for bsz in [1usize, 8, 16, 32] {
        let mut bmem = BatchMemory::new(cfg.mem_words, cfg.n_banks, bsz);
        for l in 0..bsz {
            bmem.poke_slice_lane(layout.input, l, &input.data);
            bmem.poke_slice_lane(layout.weights, l, &weights.data);
        }
        let s_b = cgra.run_decoded_batch(&dp, &mut bmem, bsz).expect("batched run");
        assert_eq!(s_b, s_scalar, "batched per-inference stats diverged from scalar");
        let r = b.run(
            &format!("executor[batched B={bsz}]: WP launch"),
            Some(slots * bsz as f64),
            || cgra.run_decoded_batch(&dp, &mut bmem, bsz).expect("run"),
        );
        let rate = slots * bsz as f64 / r.median();
        if bsz == 1 {
            b1_rate = rate;
        }
        results.row(&format!("batched_b{bsz}_slots_per_s"), rate);
        println!(
            "  B={bsz:<2}: {:.1}M PE-slots/s ({:.2}x over B=1 batched, {:.2}x over scalar)",
            rate / 1e6,
            rate / b1_rate,
            rate / (slots / after.median()),
        );
    }
    println!();

    // Profiler cost-when-on (DESIGN.md §12): the same decoded launch
    // with a cycle-attribution session active. The delta over
    // executor[decoded] is the per-step observation cost; modeled
    // stats are asserted unchanged (observe, don't perturb).
    {
        let session = openedge_cgra::obs::profile::session();
        let mut m = Memory::new(cfg.mem_words, cfg.n_banks);
        m.poke_slice(layout.input, &input.data);
        m.poke_slice(layout.weights, &weights.data);
        assert_eq!(
            cgra.run_decoded(&dp, &mut m).expect("profiled run"),
            s_scalar,
            "profiling perturbed the modeled stats"
        );
        let r = b.run(
            &format!("executor[profiled]:  WP launch ({steps} steps x {N_PES} PEs)"),
            Some(slots),
            || cgra.run_decoded(&dp, &mut mem).expect("run"),
        );
        drop(session.finish());
        results.row("profiled_slots_per_s", slots / r.median());
    }

    // Decode cost in isolation (paid once per distinct program).
    b.run("decode: WP launch program (uncached)", Some(1.0), || decode(&prog));

    // Program generation (relaunch) cost — the host-side hot path.
    b.run("program generation: WP (per launch)", Some(1.0), || {
        wp::build_program(&shape, &layout, wp::WpLaunch { k: 3, ci: 7, acc: true })
    });

    // Full convolution including all 256 launches (decoded engine +
    // decode cache end to end).
    let conv = b.run(
        "end-to-end: WP baseline conv (256 launches)",
        Some(shape.macs() as f64),
        || wp::run(&cgra, &shape, &input, &weights).expect("conv"),
    );
    results.row("wp_conv_macs_per_s", shape.macs() as f64 / conv.median());
    results.flush();
}
