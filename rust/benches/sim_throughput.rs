//! Bench: raw simulator performance — PE-slots per host second on the
//! WP steady-state loop, plus program-generation cost. The target of
//! the §Perf optimization pass (EXPERIMENTS.md): the Fig. 5 full sweep
//! must complete in minutes.
//!
//! `cargo bench --bench sim_throughput`

use openedge_cgra::benchkit::Bench;
use openedge_cgra::cgra::{Cgra, CgraConfig, Memory};
use openedge_cgra::conv::{random_input, random_weights, ConvShape};
use openedge_cgra::isa::N_PES;
use openedge_cgra::kernels::{wp, MemLayout};
use openedge_cgra::prop::Rng;

fn main() {
    let cfg = CgraConfig::default();
    let shape = ConvShape::baseline();
    let layout = MemLayout::new(&shape, 0, &cfg).expect("layout");
    let mut rng = Rng::new(1);
    let input = random_input(&shape, 10, &mut rng);
    let weights = random_weights(&shape, 9, &mut rng);
    let cgra = Cgra::new(cfg.clone()).expect("cgra");

    // Steady-state stepping rate: one WP launch, measured in PE slots.
    let prog = wp::build_program(&shape, &layout, wp::WpLaunch { k: 0, ci: 1, acc: true });
    let mut mem = Memory::new(cfg.mem_words, cfg.n_banks);
    mem.poke_slice(layout.input, &input.data);
    mem.poke_slice(layout.weights, &weights.data);
    let steps = cgra.run(&prog, &mut mem).expect("run").steps;

    let b = Bench::default();
    b.run(
        &format!("executor: WP launch ({} steps x {} PEs)", steps, N_PES),
        Some((steps * N_PES as u64) as f64),
        || cgra.run(&prog, &mut mem).expect("run"),
    );

    // Program generation (relaunch) cost — the host-side hot path.
    b.run("program generation: WP (per launch)", Some(1.0), || {
        wp::build_program(&shape, &layout, wp::WpLaunch { k: 3, ci: 7, acc: true })
    });

    // Full convolution including all 256 launches.
    b.run(
        "end-to-end: WP baseline conv (256 launches)",
        Some(shape.macs() as f64),
        || wp::run(&cgra, &shape, &input, &weights).expect("conv"),
    );
}
