//! Bench: batched vs sequential request submission through the
//! session `Engine` — quantifies the worker-pool fan-out win of
//! `submit_batch` over a `submit` loop, in requests per second.
//!
//! Three measurements over the same request set:
//!
//!   1. sequential `submit` loop (explicit tensors → every request is a
//!      real simulation, no cache involvement),
//!   2. `submit_batch` over the pool (same uncached requests),
//!   3. `submit_batch` of *seeded* requests against a warm cache —
//!      the cache-hit service rate (metrics from the memo, outputs
//!      reconstructed through the golden model).
//!
//! `cargo bench --bench engine_batch`

use openedge_cgra::benchkit::Bench;
use openedge_cgra::conv::{random_input, random_weights, ConvShape};
use openedge_cgra::coordinator::default_workers;
use openedge_cgra::engine::{ConvRequest, EngineBuilder};
use openedge_cgra::kernels::Mapping;
use openedge_cgra::prop::Rng;

fn main() {
    let workers = default_workers();
    let engine = EngineBuilder::new().workers(workers).private_cache().build().expect("engine");

    // A spread of shapes around the baseline so the batch is not one
    // repeated point (distinct simulations, uneven costs — the case the
    // pool's work stealing is for).
    let shapes: Vec<ConvShape> = (0..24)
        .map(|i| ConvShape::new3x3(4 + i % 5, 4 + (i / 5) % 5, 8 + (i % 3) * 2, 8))
        .collect();
    let mut rng = Rng::new(99);
    let tensor_reqs: Vec<ConvRequest> = shapes
        .iter()
        .map(|&s| {
            let input = random_input(&s, 20, &mut rng);
            let weights = random_weights(&s, 9, &mut rng);
            ConvRequest::with_data(s, Mapping::Wp, input, weights)
        })
        .collect();
    let n = tensor_reqs.len() as f64;
    println!("{} requests, {workers} workers\n", tensor_reqs.len());

    let b = Bench::new(1, 5);

    // 1. Sequential baseline: one request at a time.
    let seq = b.run("submit x N (sequential, uncached)", Some(n), || {
        for req in &tensor_reqs {
            engine.submit(req).expect("submit");
        }
    });

    // 2. The same requests fanned over the pool.
    let batch = b.run("submit_batch (pooled, uncached)", Some(n), || {
        for res in engine.submit_batch(&tensor_reqs) {
            res.expect("submit");
        }
    });

    // 3. Cache-hot seeded batch: warm once, then measure hit service.
    let seeded: Vec<ConvRequest> = shapes
        .iter()
        .enumerate()
        .map(|(i, &s)| ConvRequest::seeded(s, Mapping::Wp, 7000 + i as u64))
        .collect();
    for res in engine.submit_batch(&seeded) {
        res.expect("warmup");
    }
    let hot = b.run("submit_batch (pooled, cache-hot)", Some(n), || {
        for res in engine.submit_batch(&seeded) {
            assert!(res.expect("submit").cache_hit, "warm batch must hit");
        }
    });

    println!(
        "\npool fan-out: {:.2}x requests/s over sequential ({:.0} -> {:.0} req/s); \
         cache-hot batch serves {:.0} req/s",
        seq.median() / batch.median(),
        n / seq.median(),
        n / batch.median(),
        n / hot.median(),
    );
}
