//! Bench: the serving daemon's request path, in requests/inferences
//! per second over an in-process [`Daemon`] (no sockets — this
//! measures the subsystem, not the kernel's TCP stack).
//!
//! Four measurements on a small conv stack:
//!
//!   1. **hot scalar request** — registry hit, admission pricing from
//!      the planner memo, one queued scalar execution: the steady
//!      state of a single-inference tenant,
//!   2. **hot batched request** — count=8 through a batch-8 daemon:
//!      one request, one shared µop walk group,
//!   3. **cold-miss request** — a fresh net fingerprint per sample, so
//!      every request pays admission + compile + registry insert (and
//!      eventually LRU eviction),
//!   4. **stats snapshot** — the monitoring read path.
//!
//! `cargo bench --bench daemon_throughput`

use openedge_cgra::benchkit::{Bench, ResultsWriter};
use openedge_cgra::server::{Daemon, InferRequest, NetSpec, Outcome};

fn spec(seed: u64) -> NetSpec {
    NetSpec::Stack { depth: 1, c0: 2, k: 4, hw: 8, seed }
}

fn main() {
    let daemon = Daemon::builder().workers(2).batch(8).capacity(8).build();

    // Warm the hot path: tenant, planner memo, compiled artifact.
    match daemon.submit(InferRequest::new("bench", spec(7))).expect("warm request") {
        Outcome::Served(s) => assert!(!s.cache_hit, "first request must compile"),
        Outcome::Rejected(r) => panic!("warm request rejected: {}", r.detail),
    }

    let b = Bench::new(1, 5);

    // 1. Hot scalar requests: registry hit + queue + one inference.
    let hot = b.run("Daemon::submit (hot, count=1)", None, || {
        daemon.submit(InferRequest::new("bench", spec(7))).expect("hot request")
    });

    // 2. Hot batched requests: count=8 riding one walk group.
    let batched = b.run("Daemon::submit (hot, count=8)", None, || {
        let mut req = InferRequest::new("bench", spec(7));
        req.count = 8;
        daemon.submit(req).expect("batched request")
    });

    // 3. Cold misses: a fresh fingerprint every sample forces
    //    admission + compile + insert (+ LRU eviction once warm).
    let mut seed = 1000u64;
    let cold = b.run("Daemon::submit (cold miss)", None, || {
        seed += 1;
        daemon.submit(InferRequest::new("bench", spec(seed))).expect("cold request")
    });

    // 4. The stats read path.
    let stats = b.run("Daemon::stats", None, || daemon.stats());

    let hot_rps = 1.0 / hot.median();
    let batched_ips = 8.0 / batched.median();
    let mut results = ResultsWriter::new("daemon_throughput");
    results.row("hot_req_per_s", hot_rps);
    results.row("batched_inf_per_s", batched_ips);
    results.row("cold_req_per_s", 1.0 / cold.median());
    results.row("stats_reads_per_s", 1.0 / stats.median());
    results.flush();
    println!(
        "\nhot: {:.1} req/s ({:.1} inf/s at count=8, {:.2}x); cold miss: {:.1} req/s \
         ({:.2}x slower than hot); stats: {:.1} reads/s",
        hot_rps,
        batched_ips,
        batched_ips / hot_rps,
        1.0 / cold.median(),
        cold.median() / hot.median().max(1e-12),
        1.0 / stats.median(),
    );

    let snap = daemon.stats();
    println!(
        "registry after bench: {} hits / {} misses / {} evictions / {} compiles \
         (capacity {}); {} walks over {} lanes",
        snap.registry.hits,
        snap.registry.misses,
        snap.registry.evictions,
        snap.registry.compiles,
        snap.registry.capacity,
        snap.walks,
        snap.walk_lanes,
    );
    daemon.shutdown();
}
