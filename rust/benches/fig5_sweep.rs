//! Bench: regenerate Figure 5 (hyper-parameter robustness sweep).
//!
//! By default runs the *quick* grid (the interesting points: baseline,
//! the =17 imbalance points, tile multiples). Set `FIG5_FULL=1` for the
//! paper's complete protocol (C,K ∈ 16..32 step 1 then ..144 step 16;
//! Ox=Oy ∈ 16..32 step 1 then ..64 step 16) — minutes, not seconds.
//!
//! `cargo bench --bench fig5_sweep`

use openedge_cgra::benchkit::Bench;
use openedge_cgra::coordinator::SweepSpec;
use openedge_cgra::engine::EngineBuilder;
use openedge_cgra::report;

fn main() {
    let engine = EngineBuilder::new().build().expect("engine");
    let full = std::env::var("FIG5_FULL").map(|v| v == "1").unwrap_or(false);
    let spec = if full { SweepSpec::paper() } else { SweepSpec::quick() };
    println!(
        "sweep grid: {} points x {} mappings ({})\n",
        spec.points().len() / spec.mappings.len(),
        spec.mappings.len(),
        if full { "paper protocol" } else { "quick; FIG5_FULL=1 for the full grid" }
    );

    let fig = report::fig5(&engine, &spec).expect("fig5");
    println!("{}", fig.text);

    // Clear the sweep-point cache per sample: the bench's target is raw
    // sweep throughput ("minutes, not seconds"), not cache hit latency.
    let b = Bench::new(0, if full { 1 } else { 3 });
    b.run(
        &format!("fig5 sweep ({} points)", spec.points().len()),
        Some(spec.points().len() as f64),
        || {
            engine.cache().clear();
            report::fig5(&engine, &spec).expect("fig5")
        },
    );
}
