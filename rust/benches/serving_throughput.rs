//! Bench: the compile-once / run-many amortization story, in
//! inferences per second over `mobilenet-mini`.
//!
//! Four measurements:
//!
//!   1. **cold compile** — `Engine::compile` per sample: what every
//!      inference used to pay implicitly (program building, µop
//!      decoding, planner resolution, arena sizing),
//!   2. **warm compiled run** — one `CompiledNet` + one `NetCtx`, a
//!      pre-decoded allocation-free replay per sample: the serving
//!      steady state,
//!   3. **legacy per-call path** — `nn::run_network`, which compiles
//!      *and* golden-verifies on every call: the pre-refactor
//!      per-inference cost,
//!   4. **batched warm runs** — `CompiledNet::run_batch` at
//!      B ∈ {1, 8, 16, 32} lanes per shared µop walk (DESIGN.md §9,
//!      EXPERIMENTS.md E13), in inf/s with the speedup over B=1.
//!      Before timing, every B is gated on batched outputs being
//!      bit-identical to B scalar runs with unchanged modeled
//!      cycles/energy.
//!
//! The printed ratio is the amortization win: how many warm inferences
//! one compile buys, and how much faster the steady state is than the
//! compile-every-call path. Modeled cycles/energy are identical on
//! every path by construction — this bench measures host wall-clock.
//!
//! `cargo bench --bench serving_throughput`

use openedge_cgra::benchkit::{Bench, ResultsWriter};
use openedge_cgra::engine::EngineBuilder;
use openedge_cgra::nn;

fn main() {
    let preset = "mobilenet-mini";
    let net = nn::build_preset(preset, 7).expect("preset");
    let input = net.random_input(8, 7);
    let engine = EngineBuilder::new().private_cache().build().expect("engine");

    let b = Bench::new(1, 5);

    // 1. Cold compile: the full build-and-decode phase.
    let cold = b.run("Engine::compile (cold)", None, || engine.compile(&net).expect("compile"));

    // 2. Warm compiled run: artifact + context built once, replay per
    //    sample.
    let compiled = engine.compile(&net).expect("compile");
    let mut ctx = compiled.new_ctx();
    println!(
        "artifact: {} launches/inference, {} pre-decoded uops, arena {} words",
        compiled.total_launches(),
        compiled.total_uops(),
        compiled.arena_words()
    );
    let warm = b.run("CompiledNet::run (warm)", None, || {
        compiled.run(&mut ctx, &input).expect("run")
    });

    // 3. Legacy path: compile + golden verify on every call.
    let legacy = b.run("nn::run_network (compile per call)", None, || {
        nn::run_network(&engine, &net, &input).expect("run")
    });

    let mut results = ResultsWriter::new("serving_throughput");
    results.row("cold_compile_s", cold.median());
    results.row("warm_inf_per_s", 1.0 / warm.median());
    results.row("legacy_inf_per_s", 1.0 / legacy.median());
    let warm_ips = 1.0 / warm.median();
    println!(
        "\nwarm serving: {:.1} inf/s; legacy per-call path: {:.1} inf/s ({:.2}x); \
         one cold compile ({:.1} ms) amortizes in {:.1} warm inferences",
        warm_ips,
        1.0 / legacy.median(),
        legacy.median() / warm.median(),
        cold.median() * 1e3,
        cold.median() / warm.median().max(1e-12),
    );

    // 4. Batched warm runs: one shared µop walk serving B lanes.
    //    Gate each B on the differential contract first: batched
    //    outputs bit-identical to B scalar runs, modeled per-inference
    //    cycles/energy unchanged.
    println!("\nbatched serving (B lanes per shared uop walk):");
    let mut b1_ips = warm_ips;
    for bsz in [1usize, 8, 16, 32] {
        let mut bctx = compiled.new_batch_ctx(bsz);
        let inputs: Vec<_> =
            (0..bsz as u64).map(|l| net.random_input(8, 7 ^ (l << 8))).collect();
        let brun = compiled.run_batch(&mut bctx, &inputs).expect("batched run");
        for (l, inp) in inputs.iter().enumerate() {
            let srun = compiled.run(&mut ctx, inp).expect("scalar run");
            assert_eq!(
                bctx.outputs()[l].data,
                ctx.output().data,
                "batched lane {l} output diverged from the scalar run"
            );
            assert_eq!(brun.total_cycles, srun.total_cycles, "modeled cycles changed");
            assert_eq!(
                brun.total_energy_uj.to_bits(),
                srun.total_energy_uj.to_bits(),
                "modeled energy changed"
            );
        }
        let r = b.run(&format!("CompiledNet::run_batch (B={bsz})"), None, || {
            compiled.run_batch(&mut bctx, &inputs).expect("batched run")
        });
        let ips = bsz as f64 / r.median();
        if bsz == 1 {
            b1_ips = ips;
        }
        results.row(&format!("batched_b{bsz}_inf_per_s"), ips);
        println!(
            "  B={bsz:<2}: {ips:.1} inf/s ({:.2}x over B=1 batched, {:.2}x over scalar warm)",
            ips / b1_ips,
            ips / warm_ips,
        );
    }
    results.flush();
}
