# Convenience targets. Tier-1 verify is `cargo build --release &&
# cargo test -q` (see ROADMAP.md / EXPERIMENTS.md "CI ⇔ tier-1").

.PHONY: build test bench examples artifacts figures clean

build:
	cargo build --release --workspace

test:
	cargo test -q --workspace

# All bench targets (the figure generators, engine batching, planner
# vs sim, network throughput). BENCH_WARMUP / BENCH_SAMPLES env vars
# trade accuracy for speed (see benchkit).
bench:
	cargo bench --workspace

# The runnable examples (the Engine API's consumer surface; CI runs
# these too).
examples:
	cargo run --release --example quickstart
	cargo run --release --example mapping_explorer -- 16 17 16 16
	cargo run --release --example cnn_inference
	cargo run --release --example perf_driver
	cargo run --release --example asm_playground

# AOT-compile the JAX/Pallas HLO artifacts the runtime verifier and
# `cargo run -- verify` consume. Requires the Python/JAX toolchain;
# the Rust side skips loudly when these are absent.
artifacts:
	cd python && python -m compile.aot --out ../rust/artifacts

figures:
	cargo run --release -- report all --out reports

clean:
	cargo clean
	rm -rf rust/artifacts reports
